//! Table-driven sampling substrate for the workload generators and
//! latency models: Pareto (burst throughput schedule, after iGen [55]),
//! exponential (service times), log-normal (network latency), standard
//! normal, and Zipf (hot-directory skew), plus a general-purpose Walker
//! alias table for categorical draws (op mixes, weighted directory
//! pools).
//!
//! # Why tables
//!
//! Every simulated op samples several of these distributions (two+
//! network legs, a service time, a hot-directory rank), and the
//! closed-form samplers each burn transcendental math — `ln`/`exp`/
//! `powf`/`cos`/`sqrt` — per draw. At the paper's scale (§5.2: bursty
//! Spotify traces peaking far above 100k ops/s, replayed across λFS and
//! five baselines) that per-op cost dominates once the map/allocation/
//! arena overheads of PRs 1 and 4 are gone. The substrate here moves all
//! transcendental work to construction time:
//!
//! * **Continuous distributions** ([`Pareto`], [`Exp`], [`LogNormal`],
//!   [`normal`]) precompute a [`QuantileLut`]: `LUT_CELLS` = 4096
//!   inverse-CDF knots evaluated from the closed-form quantile function,
//!   stored as per-cell `(base, slope)` pairs. A sample is one
//!   [`Rng::next_u64`] draw, one shift for the cell index, one mask for
//!   the intra-cell fraction, and one fused multiply-add.
//! * **Discrete distributions** ([`Zipf`], [`Alias`]) precompute a
//!   Walker/Vose alias table. A sample is one `next_u64` draw and at
//!   most two table reads — and, unlike the continuous power-law
//!   approximation the old `Zipf` used, the alias table realizes the
//!   **exact** discrete Zipf pmf, for any `s >= 0` including `s = 1`
//!   (the old inverse-CDF formula was singular there).
//!
//! # Table construction and error bound
//!
//! [`QuantileLut::from_quantile`] evaluates the quantile function `Q` at
//! knots `u_i = i / N` for `i in 1..N`, with the end knots pulled in to
//! `u_0 = 1/(2N)` and `u_N = 1 - 1/(2N)` so distributions with infinite
//! support stay finite. Cell `i` maps `u in [i/N, (i+1)/N)` linearly
//! onto `[Q(u_i), Q(u_{i+1})]`:
//!
//! * Interior cells: the chord error of a convex/concave `Q` is bounded
//!   by `h^2/8 * max |Q''|` over the cell (`h = 1/4096`); for the
//!   distributions here that is a relative quantile error below 1% for
//!   `u in [1/N, 0.99]` (sub-0.1% through the body), verified by the
//!   differential tests against [`reference`].
//! * Tail cells: the last cells of heavy-tailed distributions are where
//!   the chord error concentrates (up to ~10% relative for Pareto
//!   `alpha = 1.5` in the final cell), and draws beyond `1 - 1/(2N)`
//!   clamp to `Q(1 - 1/(2N))` — e.g. an `Exp(1)` never exceeds
//!   `ln(2N) ≈ 9.01` and a standard normal never exceeds ~3.54. Each
//!   tail cell is hit with probability `1/4096`, so the induced moment
//!   error is far below the simulation's statistical noise (bounded by
//!   the moment differential tests).
//!
//! # Determinism contract
//!
//! Every sampler consumes **exactly one `next_u64` per sample** — LUT
//! and alias alike (the old `LogNormal` consumed two via Box–Muller).
//! Draw counts are part of the reproducibility contract: forked RNG
//! streams stay aligned across refactors only if the per-sample draw
//! count is fixed. Pinned by `one_draw_per_sample` below.
//!
//! Switching substrates intentionally shifts the sampled values for a
//! given seed: `RunMetrics::fingerprint()` / `outcome_fingerprint()`
//! values recorded before PR 5 are not comparable to post-PR-5 runs (see
//! the ROADMAP artifact-comparability note). All determinism tests pin
//! *relative* equalities (run-twice, record→replay, scalar-vs-batch), so
//! they re-pin the new values automatically.
//!
//! The pre-table closed-form samplers survive verbatim in [`reference`]
//! (the `HeapQueue`/`ReferencePlatform` pattern) and back the
//! differential tests and the `sampler` bench baseline.

use super::rng::Rng;

/// Number of interpolation cells in a [`QuantileLut`].
pub const LUT_CELLS: usize = 4096;
const LUT_BITS: u32 = LUT_CELLS.trailing_zeros(); // 12
const FRAC_BITS: u32 = 64 - LUT_BITS; // 52
const FRAC_MASK: u64 = (1u64 << FRAC_BITS) - 1;
const FRAC_SCALE: f64 = 1.0 / (1u64 << FRAC_BITS) as f64;

/// Precomputed inverse-CDF lookup table: one `(base, slope)` pair per
/// cell, sampled with a single `u64` draw (see the module doc for the
/// construction and error bound).
#[derive(Clone)]
pub struct QuantileLut {
    cells: Box<[(f64, f64)]>,
}

impl QuantileLut {
    /// Build from a closed-form quantile function `q : (0,1) -> R`.
    /// `q` must be non-decreasing; it is evaluated `LUT_CELLS + 1` times
    /// at construction and never again.
    pub fn from_quantile(q: impl Fn(f64) -> f64) -> Self {
        let n = LUT_CELLS;
        let knot_u = |i: usize| -> f64 {
            if i == 0 {
                1.0 / (2 * n) as f64
            } else if i == n {
                1.0 - 1.0 / (2 * n) as f64
            } else {
                i as f64 / n as f64
            }
        };
        let knots: Vec<f64> = (0..=n).map(|i| q(knot_u(i))).collect();
        for w in knots.windows(2) {
            debug_assert!(w[1] >= w[0], "quantile function must be non-decreasing");
        }
        let cells: Box<[(f64, f64)]> =
            (0..n).map(|i| (knots[i], knots[i + 1] - knots[i])).collect();
        QuantileLut { cells }
    }

    /// One sample: one `next_u64`, shift/mask, fused multiply-add.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let u = rng.next_u64();
        let (base, slope) = self.cells[(u >> FRAC_BITS) as usize];
        slope.mul_add((u & FRAC_MASK) as f64 * FRAC_SCALE, base)
    }

    /// The piecewise-linear quantile function the sampler realizes
    /// (test/inspection hook; `u` is clamped to `[0, 1)`).
    pub fn quantile(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0 - f64::EPSILON);
        let scaled = u * LUT_CELLS as f64;
        let i = (scaled as usize).min(LUT_CELLS - 1);
        let (base, slope) = self.cells[i];
        slope.mul_add(scaled - i as f64, base)
    }
}

impl std::fmt::Debug for QuantileLut {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (lo, _) = self.cells[0];
        let (base, slope) = self.cells[self.cells.len() - 1];
        write!(f, "QuantileLut({} cells, [{lo:.6}, {:.6}])", self.cells.len(), base + slope)
    }
}

/// Inverse standard-normal CDF (Acklam's rational approximation,
/// |relative error| < 1.15e-9). Construction-time only — never on a
/// sampling path.
fn inv_normal_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "inv_normal_cdf domain: {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Pareto(x_m, alpha) over a quantile LUT; the exact inverse CDF
/// `x_m * (1-u)^(-1/alpha)` lives in [`reference::Pareto`] and in the
/// AOT-lowered `pareto_schedule` artifact (`python/compile/model.py`).
#[derive(Clone, Debug)]
pub struct Pareto {
    // Parameters are private: the LUT is baked at construction, so a
    // mutable parameter field would silently desync from sampling.
    scale: f64,
    shape: f64,
    lut: QuantileLut,
}

impl Pareto {
    pub fn new(scale: f64, shape: f64) -> Self {
        assert!(scale > 0.0 && shape > 0.0);
        let lut = QuantileLut::from_quantile(|u| scale * (1.0 - u).powf(-1.0 / shape));
        Pareto { scale, shape, lut }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.lut.sample(rng)
    }

    /// Sample clamped to `cap` (the paper clamps bursts at 7x base).
    #[inline]
    pub fn sample_capped(&self, rng: &mut Rng, cap: f64) -> f64 {
        self.sample(rng).min(cap)
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    pub fn shape(&self) -> f64 {
        self.shape
    }
}

/// Exponential(rate) over a quantile LUT (`Q(u) = -ln(1-u)/rate`).
#[derive(Clone, Debug)]
pub struct Exp {
    rate: f64,
    lut: QuantileLut,
}

impl Exp {
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        let lut = QuantileLut::from_quantile(|u| -(1.0 - u).ln() / rate);
        Exp { rate, lut }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.lut.sample(rng)
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }
}

/// Log-normal parameterized by the *target* median and sigma of the
/// underlying normal — a good fit for network RTT tails. Sampled from a
/// quantile LUT over `Q(u) = exp(mu + sigma * Phi^-1(u))`.
#[derive(Clone, Debug)]
pub struct LogNormal {
    mu: f64,
    sigma: f64,
    lut: QuantileLut,
}

impl LogNormal {
    /// `median` is exp(mu).
    pub fn from_median(median: f64, sigma: f64) -> Self {
        assert!(median > 0.0 && sigma >= 0.0);
        let mu = median.ln();
        let lut = QuantileLut::from_quantile(|u| (mu + sigma * inv_normal_cdf(u)).exp());
        LogNormal { mu, sigma, lut }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.lut.sample(rng)
    }

    pub fn mu(&self) -> f64 {
        self.mu
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }
}

/// Standard normal over a process-wide quantile LUT (built once on first
/// use). One `next_u64` per sample — the Box–Muller reference
/// ([`reference::normal`]) consumed two.
pub fn normal(rng: &mut Rng) -> f64 {
    use std::sync::OnceLock;
    static STD_NORMAL: OnceLock<QuantileLut> = OnceLock::new();
    STD_NORMAL.get_or_init(|| QuantileLut::from_quantile(inv_normal_cdf)).sample(rng)
}

/// Walker/Vose alias table over arbitrary non-negative weights: O(n)
/// construction, O(1) sampling (one `next_u64`, at most two table
/// reads). The high 32 bits of the draw pick the column (Lemire
/// multiply-shift), the low 32 bits decide accept-vs-alias.
#[derive(Clone)]
pub struct Alias {
    /// `(accept threshold in [0,1], alias index)` per column.
    cols: Box<[(f64, u32)]>,
}

impl Alias {
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0 && n <= u32::MAX as usize);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "weights must sum to a positive finite value");
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights
            .iter()
            .map(|&w| {
                assert!(w >= 0.0 && w.is_finite(), "bad weight {w}");
                w * scale
            })
            .collect();
        let mut alias: Vec<u32> = (0..n as u32).collect();
        let mut small: Vec<u32> = Vec::new();
        let mut large: Vec<u32> = Vec::new();
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while !small.is_empty() && !large.is_empty() {
            let s = small.pop().unwrap();
            let l = *large.last().unwrap();
            alias[s as usize] = l;
            // `l` donates the mass that fills column `s` to 1.
            prob[l as usize] -= 1.0 - prob[s as usize];
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Float residue: any column still queued holds (within rounding)
        // exactly its own mass.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
        }
        let cols: Box<[(f64, u32)]> = prob.into_iter().zip(alias).collect();
        Alias { cols }
    }

    pub fn len(&self) -> usize {
        self.cols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// One sample: one `next_u64`, column via multiply-shift on the high
    /// half, accept-vs-alias via the low half.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_u64();
        let col = (((u >> 32) * self.cols.len() as u64) >> 32) as usize;
        let (accept, alias) = self.cols[col];
        if ((u & 0xFFFF_FFFF) as f64) * (1.0 / 4_294_967_296.0) < accept {
            col
        } else {
            alias as usize
        }
    }
}

impl std::fmt::Debug for Alias {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Alias({} cols)", self.cols.len())
    }
}

/// Exact discrete Zipf over ranks `0..n`: `P(k) = (k+1)^-s / H_{n,s}`,
/// realized as a Walker alias table — strictly better than the old
/// continuous power-law approximation (which also could not represent
/// `s = 1`; the alias table handles any `s >= 0` uniformly).
///
/// Used for hot-directory skew in the namespace generator: a small set
/// of directories receives most metadata operations, which is what makes
/// λFS' per-deployment auto-scaling matter (§3.3).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    alias: Alias,
}

impl Zipf {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n > 0 && n <= u32::MAX as u64);
        assert!(s >= 0.0 && s.is_finite(), "bad Zipf exponent {s}");
        let weights: Vec<f64> = (0..n).map(|k| ((k + 1) as f64).powf(-s)).collect();
        Zipf { n, alias: Alias::new(&weights) }
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    /// Sample a rank in `[0, n)` (0 = hottest for s > 0).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        self.alias.sample(rng) as u64
    }
}

/// The pre-table closed-form samplers, retained verbatim as the
/// differential baseline (the `HeapQueue`/`ReferencePlatform` pattern).
/// Statistical-equivalence tests compare these against the table-driven
/// substrate; the `sampler` hot spot in `benches/perf_simulator.rs`
/// measures both over identical draw streams.
pub mod reference {
    use crate::util::rng::Rng;

    /// Closed-form Pareto: `x_m * (1-u)^(-1/alpha)` per draw.
    #[derive(Clone, Copy, Debug)]
    pub struct Pareto {
        pub scale: f64,
        pub shape: f64,
    }

    impl Pareto {
        pub fn new(scale: f64, shape: f64) -> Self {
            assert!(scale > 0.0 && shape > 0.0);
            Pareto { scale, shape }
        }

        pub fn sample(&self, rng: &mut Rng) -> f64 {
            let u = rng.f64().min(1.0 - 1e-12);
            self.scale * (1.0 - u).powf(-1.0 / self.shape)
        }

        pub fn sample_capped(&self, rng: &mut Rng, cap: f64) -> f64 {
            self.sample(rng).min(cap)
        }

        /// Closed-form quantile (shared with the LUT differential tests).
        pub fn quantile(&self, u: f64) -> f64 {
            self.scale * (1.0 - u).powf(-1.0 / self.shape)
        }
    }

    /// Closed-form Exponential(rate): one `ln` per draw.
    #[derive(Clone, Copy, Debug)]
    pub struct Exp {
        pub rate: f64,
    }

    impl Exp {
        pub fn new(rate: f64) -> Self {
            assert!(rate > 0.0);
            Exp { rate }
        }

        pub fn sample(&self, rng: &mut Rng) -> f64 {
            let u = rng.f64().max(1e-300);
            -u.ln() / self.rate
        }

        pub fn quantile(&self, u: f64) -> f64 {
            -(1.0 - u).ln() / self.rate
        }
    }

    /// Closed-form log-normal: Box–Muller normal (two draws) + `exp`.
    #[derive(Clone, Copy, Debug)]
    pub struct LogNormal {
        pub mu: f64,
        pub sigma: f64,
    }

    impl LogNormal {
        pub fn from_median(median: f64, sigma: f64) -> Self {
            assert!(median > 0.0 && sigma >= 0.0);
            LogNormal { mu: median.ln(), sigma }
        }

        pub fn sample(&self, rng: &mut Rng) -> f64 {
            (self.mu + self.sigma * normal(rng)).exp()
        }

        pub fn quantile(&self, u: f64) -> f64 {
            (self.mu + self.sigma * super::inv_normal_cdf(u)).exp()
        }
    }

    /// Standard normal via Box–Muller (two uniform draws per value).
    pub fn normal(rng: &mut Rng) -> f64 {
        let u1 = rng.f64().max(1e-300);
        let u2 = rng.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// The old Zipf-like rank distribution over `0..n`: continuous
    /// power-law inverse CDF (pdf ∝ x^-s on [1, n+1), floored to a
    /// rank). An *approximation* of discrete Zipf — head/tail mass
    /// ratios are preserved, exact pmf values are not; the table-driven
    /// [`super::Zipf`] is exact. Supports `s = 1` via the logarithmic
    /// inverse CDF (the power-law formula is singular there).
    #[derive(Clone, Copy, Debug)]
    pub struct Zipf {
        n: u64,
        one_minus_s: f64,
        span: f64,
    }

    impl Zipf {
        pub fn new(n: u64, s: f64) -> Self {
            assert!(n > 0 && s >= 0.0 && s.is_finite());
            let one_minus_s = 1.0 - s;
            // For s = 1 the CDF is ln(x)/ln(n+1); flag with span = 0.
            let span = if (s - 1.0).abs() <= 1e-9 {
                0.0
            } else {
                ((n + 1) as f64).powf(one_minus_s) - 1.0
            };
            Zipf { n, one_minus_s, span }
        }

        /// Sample a rank in `[0, n)` (0 = hottest when s > 0).
        pub fn sample(&self, rng: &mut Rng) -> u64 {
            let u = rng.f64();
            let x = if self.span == 0.0 {
                (u * ((self.n + 1) as f64).ln()).exp()
            } else {
                (u * self.span + 1.0).powf(1.0 / self.one_minus_s)
            };
            let k = x as u64; // floor; x >= 1 so k >= 1
            k.clamp(1, self.n) - 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234)
    }

    #[test]
    fn pareto_support_and_mean() {
        let mut r = rng();
        let p = Pareto::new(25_000.0, 2.0);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = p.sample(&mut r);
            assert!(x >= 25_000.0);
            sum += x.min(1e7); // trim the unbounded tail for the mean check
        }
        // E[X] = scale * shape / (shape - 1) = 50_000 for alpha=2.
        let mean = sum / n as f64;
        assert!((mean - 50_000.0).abs() < 2_500.0, "mean {mean}");
    }

    #[test]
    fn pareto_cap_respected() {
        let mut r = rng();
        let p = Pareto::new(25_000.0, 2.0);
        for _ in 0..10_000 {
            assert!(p.sample_capped(&mut r, 7.0 * 25_000.0) <= 7.0 * 25_000.0);
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = rng();
        let e = Exp::new(0.5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| e.sample(&mut r)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = rng();
        let ln = LogNormal::from_median(1.5, 0.3);
        let mut xs: Vec<f64> = (0..20_001).map(|_| ln.sample(&mut r)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[10_000];
        assert!((med - 1.5).abs() < 0.1, "median {med}");
    }

    #[test]
    fn normal_moments() {
        let mut r = rng();
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| normal(&mut r)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_rank_zero_hottest() {
        let mut r = rng();
        let z = Zipf::new(1000, 1.2);
        let mut counts = vec![0u32; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        assert!(counts[0] > counts[10], "rank 0 hotter than rank 10");
        assert!(counts[0] > counts[100] * 2, "strong skew");
    }

    #[test]
    fn zipf_in_range() {
        let mut r = rng();
        let z = Zipf::new(50, 1.5);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 50);
        }
    }

    /// Exact discrete pmf for Zipf(n, s) — the distribution the alias
    /// table must realize.
    fn zipf_pmf(n: usize, s: f64) -> Vec<f64> {
        let w: Vec<f64> = (0..n).map(|k| ((k + 1) as f64).powf(-s)).collect();
        let total: f64 = w.iter().sum();
        w.into_iter().map(|x| x / total).collect()
    }

    #[test]
    fn zipf_alias_matches_exact_discrete_pmf() {
        // The head probabilities of the exact discrete pmf — which the
        // old continuous approximation got visibly wrong (e.g. rank 0 at
        // n=1000, s=1.3: ~0.28 exact vs ~0.21 continuous).
        let (n, s) = (1000usize, 1.3);
        let pmf = zipf_pmf(n, s);
        let z = Zipf::new(n as u64, s);
        let draws = 400_000u32;
        let mut counts = vec![0u32; n];
        let mut r = Rng::new(777);
        for _ in 0..draws {
            counts[z.sample(&mut r) as usize] += 1;
        }
        for k in [0usize, 1, 2, 5, 10] {
            let emp = counts[k] as f64 / draws as f64;
            let rel = (emp - pmf[k]).abs() / pmf[k];
            assert!(rel < 0.05, "rank {k}: empirical {emp} vs pmf {}", pmf[k]);
        }
        // Empirical mean rank vs the analytic expectation.
        let mean: f64 = counts.iter().enumerate().map(|(k, &c)| k as f64 * c as f64).sum::<f64>()
            / draws as f64;
        let expect: f64 = pmf.iter().enumerate().map(|(k, &p)| k as f64 * p).sum();
        assert!((mean - expect).abs() / expect < 0.05, "mean {mean} vs {expect}");
    }

    #[test]
    fn zipf_supports_s_equal_one() {
        // The satellite fix: s = 1 used to assert; the alias table
        // handles it exactly (P(k) = 1/((k+1) H_n)).
        let (n, s) = (500usize, 1.0);
        let z = Zipf::new(n as u64, s);
        let pmf = zipf_pmf(n, s);
        let mut counts = vec![0u32; n];
        let draws = 300_000u32;
        let mut r = Rng::new(31);
        for _ in 0..draws {
            let k = z.sample(&mut r) as usize;
            assert!(k < n);
            counts[k] += 1;
        }
        let emp0 = counts[0] as f64 / draws as f64;
        assert!((emp0 - pmf[0]).abs() / pmf[0] < 0.05, "head {emp0} vs {}", pmf[0]);
        assert!(counts[0] > counts[9], "rank 0 hotter than rank 9");
        // The retained continuous reference also supports s = 1 now
        // (ln-based inverse CDF) and stays in range.
        let zr = reference::Zipf::new(n as u64, s);
        for _ in 0..10_000 {
            assert!(zr.sample(&mut r) < n as u64);
        }
    }

    #[test]
    fn alias_uniform_and_degenerate() {
        let mut r = rng();
        // Uniform weights: all columns accept at ~1.0.
        let a = Alias::new(&[1.0; 7]);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            seen[a.sample(&mut r)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all columns reachable");
        // Degenerate: one positive weight captures every draw.
        let d = Alias::new(&[0.0, 3.0, 0.0]);
        for _ in 0..1_000 {
            assert_eq!(d.sample(&mut r), 1);
        }
    }

    #[test]
    fn alias_frequencies_match_weights() {
        let weights = [5.0, 1.0, 3.0, 0.5, 0.5];
        let total: f64 = weights.iter().sum();
        let a = Alias::new(&weights);
        let mut counts = [0u32; 5];
        let draws = 200_000u32;
        let mut r = Rng::new(99);
        for _ in 0..draws {
            counts[a.sample(&mut r)] += 1;
        }
        for (i, &w) in weights.iter().enumerate() {
            let emp = counts[i] as f64 / draws as f64;
            let expect = w / total;
            assert!((emp - expect).abs() < 0.01, "col {i}: {emp} vs {expect}");
        }
    }

    /// The substrate determinism contract: every sampler consumes exactly
    /// one `next_u64` per sample.
    #[test]
    fn one_draw_per_sample() {
        fn assert_one_draw(label: &str, mut f: impl FnMut(&mut Rng)) {
            let mut a = Rng::new(0xd4a3);
            let mut b = Rng::new(0xd4a3);
            for _ in 0..64 {
                f(&mut a);
                b.next_u64();
            }
            for _ in 0..8 {
                assert_eq!(a.next_u64(), b.next_u64(), "{label} draw count != 1");
            }
        }
        let p = Pareto::new(25_000.0, 2.0);
        assert_one_draw("Pareto", |r| {
            p.sample(r);
        });
        let e = Exp::new(0.5);
        assert_one_draw("Exp", |r| {
            e.sample(r);
        });
        let ln = LogNormal::from_median(1.5, 0.3);
        assert_one_draw("LogNormal", |r| {
            ln.sample(r);
        });
        assert_one_draw("normal", |r| {
            normal(r);
        });
        let z = Zipf::new(4096, 1.3);
        assert_one_draw("Zipf", |r| {
            z.sample(r);
        });
        let a = Alias::new(&[2.0, 1.0, 1.0]);
        assert_one_draw("Alias", |r| {
            a.sample(r);
        });
    }

    /// Differential: the LUT's piecewise-linear quantile tracks the
    /// closed-form quantile within the documented error bound — sub-1%
    /// through `u <= 0.99`, bounded through the tail cells.
    #[test]
    fn quantile_lut_tracks_closed_form() {
        struct Case {
            name: &'static str,
            lut: QuantileLut,
            q: Box<dyn Fn(f64) -> f64>,
        }
        let pareto = reference::Pareto::new(25_000.0, 2.0);
        let pareto_heavy = reference::Pareto::new(1.0, 1.5);
        let exp = reference::Exp::new(0.5);
        let logn = reference::LogNormal::from_median(8.0, 0.6);
        let cases = [
            Case {
                name: "pareto(a=2)",
                lut: Pareto::new(25_000.0, 2.0).lut,
                q: Box::new(move |u| pareto.quantile(u)),
            },
            Case {
                name: "pareto(a=1.5)",
                lut: Pareto::new(1.0, 1.5).lut,
                q: Box::new(move |u| pareto_heavy.quantile(u)),
            },
            Case {
                name: "exp",
                lut: Exp::new(0.5).lut,
                q: Box::new(move |u| exp.quantile(u)),
            },
            Case {
                name: "lognormal",
                lut: LogNormal::from_median(8.0, 0.6).lut,
                q: Box::new(move |u| logn.quantile(u)),
            },
        ];
        let n = LUT_CELLS as f64;
        for c in &cases {
            // Cell midpoints are the worst case for chord interpolation.
            let mut worst_body = 0.0f64;
            let mut worst_tail = 0.0f64;
            for i in 1..LUT_CELLS - 1 {
                let u = (i as f64 + 0.5) / n;
                let rel = ((c.lut.quantile(u) - (c.q)(u)) / (c.q)(u)).abs();
                if u <= 0.99 {
                    worst_body = worst_body.max(rel);
                } else {
                    worst_tail = worst_tail.max(rel);
                }
            }
            assert!(worst_body < 0.01, "{}: body error {worst_body}", c.name);
            assert!(worst_tail < 0.12, "{}: tail error {worst_tail}", c.name);
        }
    }

    /// Differential: sampled moments of the table-driven substrate agree
    /// with the retained closed-form reference across seeds.
    #[test]
    fn moments_match_reference_across_seeds() {
        for seed in [1u64, 42, 0xfeed] {
            let n = 60_000;
            let mean = |f: &mut dyn FnMut(&mut Rng) -> f64, seed: u64| -> f64 {
                let mut r = Rng::new(seed);
                (0..n).map(|_| f(&mut r)).sum::<f64>() / n as f64
            };

            let e = Exp::new(0.5);
            let er = reference::Exp::new(0.5);
            let m_lut = mean(&mut |r| e.sample(r), seed);
            let m_ref = mean(&mut |r| er.sample(r), seed);
            assert!((m_lut - m_ref).abs() / m_ref < 0.03, "exp {m_lut} vs {m_ref}");

            let l = LogNormal::from_median(8.0, 0.6);
            let lr = reference::LogNormal::from_median(8.0, 0.6);
            let m_lut = mean(&mut |r| l.sample(r), seed);
            let m_ref = mean(&mut |r| lr.sample(r), seed);
            assert!((m_lut - m_ref).abs() / m_ref < 0.03, "lognormal {m_lut} vs {m_ref}");

            // Pareto's unbounded tail is trimmed like the support test.
            let p = Pareto::new(25_000.0, 2.0);
            let pr = reference::Pareto::new(25_000.0, 2.0);
            let m_lut = mean(&mut |r| p.sample(r).min(1e7), seed);
            let m_ref = mean(&mut |r| pr.sample(r).min(1e7), seed);
            assert!((m_lut - m_ref).abs() / m_ref < 0.04, "pareto {m_lut} vs {m_ref}");
        }
    }

    #[test]
    fn lut_quantile_hits_exact_knots() {
        // Grid knots are evaluated exactly from the closed form: the
        // median of a LogNormal LUT is the requested median.
        let l = LogNormal::from_median(1.5, 0.3);
        assert!((l.lut.quantile(0.5) - 1.5).abs() < 1e-12);
        // Monotone across the whole table.
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=4096 {
            let v = l.lut.quantile(i as f64 / 4096.0);
            assert!(v >= prev, "quantile must be monotone at {i}");
            prev = v;
        }
    }
}
