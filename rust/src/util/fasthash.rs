//! Deterministic FNV-backed hashing for the simulation hot path.
//!
//! `std::collections::HashMap`'s default `RandomState` (SipHash-1-3) is
//! DoS-resistant but costs tens of nanoseconds per small key — far too
//! much for per-operation lookups in `InternedCache`, `NdbStore`, and
//! `ConnectionTable`, whose keys are 4–12 byte interned ids produced by
//! the simulator itself (no untrusted input, so hash-flooding is not a
//! threat model here). [`FnvBuildHasher`] swaps in the crate's FNV-1a
//! constants (`util::fnv`) in the style of `rustc`'s `FxHashMap`:
//!
//! * integer writes fold the value in one xor-multiply round each —
//!   one multiply per `u32` key instead of a full SipHash permutation;
//! * byte-slice writes run plain streaming FNV-1a;
//! * a final avalanche (xor-shift-multiply) spreads entropy into the low
//!   bits hashbrown uses for bucket selection, which raw FNV concentrates
//!   in the high bits for short keys.
//!
//! Determinism: the hasher is keyless, so iteration order of a
//! [`FastMap`] depends only on the insertion history — one source of
//! run-to-run nondeterminism (`RandomState`'s per-process seeds) removed
//! from the simulator wholesale.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasher, Hasher};

use super::fnv;

const FNV64_OFFSET: u64 = 0xcbf29ce484222325;
const FNV64_PRIME: u64 = 0x100000001b3;

/// Streaming FNV-1a hasher with per-word folding for integer keys.
#[derive(Clone, Debug)]
pub struct FnvHasher {
    state: u64,
}

impl Default for FnvHasher {
    #[inline]
    fn default() -> Self {
        FnvHasher { state: FNV64_OFFSET }
    }
}

impl FnvHasher {
    /// One xor-multiply round over a 64-bit word.
    #[inline]
    fn fold(&mut self, word: u64) {
        self.state = (self.state ^ word).wrapping_mul(FNV64_PRIME);
    }
}

impl Hasher for FnvHasher {
    #[inline]
    fn finish(&self) -> u64 {
        // Finalizing avalanche (splitmix64 tail): FNV leaves short keys'
        // entropy in the high bits; hashbrown indexes buckets by the low
        // bits, so mix before handing the value over.
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.fold(b as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.fold(v as u64);
    }

    #[inline]
    fn write_u16(&mut self, v: u16) {
        self.fold(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.fold(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.fold(v);
    }

    #[inline]
    fn write_u128(&mut self, v: u128) {
        self.fold(v as u64);
        self.fold((v >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.fold(v as u64);
    }

    #[inline]
    fn write_i8(&mut self, v: i8) {
        self.fold(v as u8 as u64);
    }

    #[inline]
    fn write_i32(&mut self, v: i32) {
        self.fold(v as u32 as u64);
    }

    #[inline]
    fn write_i64(&mut self, v: i64) {
        self.fold(v as u64);
    }
}

/// Keyless `BuildHasher` producing [`FnvHasher`]s — the `FxHashMap`-style
/// replacement for `RandomState` on the simulation hot path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FnvBuildHasher;

impl BuildHasher for FnvBuildHasher {
    type Hasher = FnvHasher;

    #[inline]
    fn build_hasher(&self) -> FnvHasher {
        FnvHasher::default()
    }
}

/// `HashMap` keyed by the deterministic FNV hasher.
pub type FastMap<K, V> = HashMap<K, V, FnvBuildHasher>;

/// `HashSet` keyed by the deterministic FNV hasher.
pub type FastSet<K> = HashSet<K, FnvBuildHasher>;

/// Hash one byte slice to completion (convenience for digests).
#[inline]
pub fn hash_bytes(data: &[u8]) -> u64 {
    let mut h = FnvHasher::default();
    h.write(data);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::namespace::{DirId, InodeRef};
    use std::hash::Hash;

    fn hash_one<T: Hash>(v: &T) -> u64 {
        let mut h = FnvBuildHasher.build_hasher();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn byte_stream_matches_fnv1a() {
        // The streaming byte path is plain FNV-1a before the avalanche:
        // two equal streams must agree however they are chunked.
        let mut a = FnvHasher::default();
        a.write(b"hello world");
        let mut b = FnvHasher::default();
        b.write(b"hello");
        b.write(b" world");
        assert_eq!(a.finish(), b.finish());
        // And relate to the canonical fnv1a64 (pre-avalanche state).
        assert_eq!(fnv::fnv1a64(b""), FNV64_OFFSET);
    }

    #[test]
    fn deterministic_across_builders() {
        let k = InodeRef::file(DirId(42), 7);
        assert_eq!(hash_one(&k), hash_one(&k));
        let m1: FastMap<u32, u32> = (0..100).map(|i| (i, i)).collect();
        let m2: FastMap<u32, u32> = (0..100).map(|i| (i, i)).collect();
        let k1: Vec<u32> = m1.keys().copied().collect();
        let k2: Vec<u32> = m2.keys().copied().collect();
        assert_eq!(k1, k2, "iteration order is reproducible");
    }

    #[test]
    fn distinct_keys_distinct_hashes() {
        let mut seen = std::collections::HashSet::new();
        for d in 0..100u32 {
            for f in [None, Some(0u32), Some(1)] {
                let h = hash_one(&InodeRef { dir: DirId(d), file: f });
                assert!(seen.insert(h), "collision at dir {d} file {f:?}");
            }
        }
    }

    #[test]
    fn low_bits_spread_for_sequential_keys() {
        // hashbrown picks buckets from the low bits: sequential interned
        // ids must not collapse onto a few residues.
        let mut residues = std::collections::HashSet::new();
        for i in 0..256u32 {
            residues.insert(hash_one(&i) & 0xff);
        }
        assert!(residues.len() > 150, "only {} residues", residues.len());
    }

    #[test]
    fn map_behaves_like_std() {
        let mut m: FastMap<InodeRef, u64> = FastMap::default();
        let k = InodeRef::dir(DirId(3));
        assert_eq!(m.insert(k, 1), None);
        assert_eq!(m.insert(k, 2), Some(1));
        assert_eq!(m.get(&k), Some(&2));
        assert_eq!(m.remove(&k), Some(2));
        assert!(m.is_empty());
        let mut s: FastSet<(u32, u32)> = FastSet::default();
        assert!(s.insert((1, 2)));
        assert!(!s.insert((1, 2)));
    }

    #[test]
    fn hash_bytes_stable() {
        assert_eq!(hash_bytes(b"abc"), hash_bytes(b"abc"));
        assert_ne!(hash_bytes(b"abc"), hash_bytes(b"abd"));
    }
}
