//! FNV-1a hashing — the routing contract shared with the L1 Pallas kernel.
//!
//! `fnv1a32` MUST stay bit-identical to `python/compile/kernels/route_hash.py`
//! (asserted by `rust/tests/runtime_artifacts.rs` against the compiled HLO
//! artifact and by unit vectors here). λFS partitions the DFS namespace by
//! `fnv1a32(parent_dir_bytes[..min(len, PATH_WIDTH)]) % n_deployments`.

/// Max path bytes the router hashes; mirrors `route_hash.PATH_WIDTH`.
pub const PATH_WIDTH: usize = 128;

const FNV32_OFFSET: u32 = 2166136261;
const FNV32_PRIME: u32 = 16777619;
const FNV64_OFFSET: u64 = 0xcbf29ce484222325;
const FNV64_PRIME: u64 = 0x100000001b3;

/// 32-bit FNV-1a over `data` (the kernel contract).
#[inline]
pub fn fnv1a32(data: &[u8]) -> u32 {
    let mut h = FNV32_OFFSET;
    for &b in data {
        h = (h ^ b as u32).wrapping_mul(FNV32_PRIME);
    }
    h
}

/// 64-bit FNV-1a (internal hashing: RNG stream labels, map keys).
#[inline]
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut h = FNV64_OFFSET;
    for &b in data {
        h = (h ^ b as u64).wrapping_mul(FNV64_PRIME);
    }
    h
}

/// The λFS routing function: hash the first `PATH_WIDTH` bytes of the
/// parent-directory path, reduce modulo the deployment count.
#[inline]
pub fn route(parent_path: &str, n_deployments: u32) -> u32 {
    let bytes = parent_path.as_bytes();
    let take = bytes.len().min(PATH_WIDTH);
    fnv1a32(&bytes[..take]) % n_deployments.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a 32-bit test vectors.
        assert_eq!(fnv1a32(b""), 0x811c9dc5);
        assert_eq!(fnv1a32(b"a"), 0xe40c292c);
        assert_eq!(fnv1a32(b"foobar"), 0xbf9cf968);
    }

    #[test]
    fn known_vectors_64() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn route_is_stable_and_bounded() {
        for n in 1..20 {
            let d = route("/some/dir", n);
            assert!(d < n);
            assert_eq!(d, route("/some/dir", n), "deterministic");
        }
    }

    #[test]
    fn route_truncates_at_path_width() {
        let long = "x".repeat(PATH_WIDTH + 50);
        let trunc = "x".repeat(PATH_WIDTH);
        assert_eq!(route(&long, 97), route(&trunc, 97));
    }

    #[test]
    fn route_n_zero_clamps() {
        assert_eq!(route("/a", 0), 0);
    }

    #[test]
    fn distinct_dirs_spread() {
        let n = 8u32;
        let mut counts = vec![0u32; n as usize];
        for i in 0..800 {
            counts[route(&format!("/user{i}/data"), n) as usize] += 1;
        }
        let fair = 800 / n;
        assert!(counts.iter().all(|&c| c > fair / 3 && c < fair * 3), "{counts:?}");
    }
}
