//! Latency histograms, percentiles, and CDFs (the paper's Figure 10).
//!
//! Log-bucketed histogram: ~1% relative resolution across nine decades of
//! microseconds, constant memory, mergeable — what HdrHistogram does, at
//! the scale this project needs.

/// Log-bucketed histogram over positive values (typically µs latencies).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// buckets[i] counts values in [lo * G^i, lo * G^(i+1)).
    buckets: Vec<u64>,
    lo: f64,
    growth: f64,
    inv_log_growth: f64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// 1 µs .. ~17 minutes at 1% resolution.
    pub fn new() -> Self {
        Self::with_range(1.0, 1.01, 2200)
    }

    pub fn with_range(lo: f64, growth: f64, n_buckets: usize) -> Self {
        assert!(lo > 0.0 && growth > 1.0 && n_buckets > 0);
        Histogram {
            buckets: vec![0; n_buckets],
            lo,
            growth,
            inv_log_growth: 1.0 / growth.ln(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    fn index(&self, v: f64) -> usize {
        if v <= self.lo {
            return 0;
        }
        let i = ((v / self.lo).ln() * self.inv_log_growth) as usize;
        i.min(self.buckets.len() - 1)
    }

    pub fn record(&mut self, v: f64) {
        debug_assert!(v.is_finite() && v >= 0.0, "bad sample {v}");
        let idx = self.index(v.max(0.0));
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Approximate quantile `q` in [0,1] (bucket upper edge).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let edge = self.lo * self.growth.powi(i as i32 + 1);
                return edge.min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Order-sensitive digest of the full histogram state (bucket counts,
    /// count, sum/min/max bit patterns) — used by the determinism
    /// regression tests to compare two runs exactly.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fasthash::FnvHasher::default();
        use std::hash::Hasher;
        h.write_u64(self.count);
        h.write_u64(self.sum.to_bits());
        h.write_u64(self.min.to_bits());
        h.write_u64(self.max.to_bits());
        for (i, &c) in self.buckets.iter().enumerate() {
            if c != 0 {
                h.write_usize(i);
                h.write_u64(c);
            }
        }
        h.finish()
    }

    /// CDF as `(value, cumulative_fraction)` points over non-empty buckets —
    /// directly plottable as the paper's Figure 10.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        if self.count == 0 {
            return out;
        }
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            acc += c;
            let edge = self.lo * self.growth.powi(i as i32 + 1);
            out.push((edge.min(self.max), acc as f64 / self.count as f64));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert!(h.cdf().is_empty());
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in [10.0, 20.0, 30.0] {
            h.record(v);
        }
        assert!((h.mean() - 20.0).abs() < 1e-9);
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 10.0);
        assert_eq!(h.max(), 30.0);
    }

    #[test]
    fn quantiles_within_resolution() {
        let mut h = Histogram::new();
        for i in 1..=10_000 {
            h.record(i as f64);
        }
        let p50 = h.p50();
        let p99 = h.p99();
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.03, "p50={p50}");
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.03, "p99={p99}");
    }

    #[test]
    fn cdf_monotone_and_complete() {
        let mut h = Histogram::new();
        for i in 0..1000 {
            h.record((i % 97) as f64 + 1.0);
        }
        let cdf = h.cdf();
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0, "values monotone");
            assert!(w[0].1 <= w[1].1, "fractions monotone");
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for i in 0..500 {
            let v = (i as f64) * 3.7 + 1.0;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert!((a.mean() - c.mean()).abs() < 1e-9);
        assert_eq!(a.p50(), c.p50());
    }

    #[test]
    fn quantile_extremes() {
        let mut h = Histogram::new();
        for v in [5.0, 500.0, 50_000.0] {
            h.record(v);
        }
        assert!(h.quantile(0.0) <= h.quantile(1.0));
        assert!(h.quantile(1.0) >= 50_000.0 * 0.98);
    }
}
