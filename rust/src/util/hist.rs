//! Latency histograms, percentiles, and CDFs (the paper's Figure 10).
//!
//! Integer-bucketed histogram, HdrHistogram-style: values (µs, fixed
//! point) index into log2 segments with `SUB_BUCKETS` linear sub-buckets
//! each, so a record is a `leading_zeros` + shift/mask — no `ln` on the
//! per-op record path (the old log-bucketed implementation, one `ln` per
//! record, survives as [`reference::LnHistogram`] for the differential
//! tests and the `hist` bench baseline). Resolution is `1/SUB_BUCKETS`
//! (< 1%) across the full `u64` range, constant memory, mergeable.
//!
//! Bucket layout: segment 0 covers `[0, SUB_BUCKETS)` exactly (one
//! bucket per µs); segment `g >= 1` covers `[SUB_BUCKETS << (g-1),
//! SUB_BUCKETS << g)` in `SUB_BUCKETS` linear sub-buckets of width
//! `2^(g-1)`. The mapping is continuous (the last bucket of segment `g`
//! abuts the first of `g+1`), covers all of `u64`, and is exact below
//! `2 * SUB_BUCKETS`.

/// Linear sub-buckets per log2 segment: 128 → worst-case relative
/// resolution 1/128 ≈ 0.8%.
const SUB_BUCKETS: usize = 128;
const SUB_BITS: u32 = SUB_BUCKETS.trailing_zeros(); // 7
/// Segments 1..=57 cover `[128, u64::MAX]`; segment 0 is the exact
/// linear region.
const SEGMENTS: usize = 64 - SUB_BITS as usize; // 57
const N_BUCKETS: usize = (SEGMENTS + 1) << SUB_BITS; // 7424

/// Integer-bucketed histogram over non-negative values (µs latencies).
///
/// `count`/`sum`/`min`/`max` (and therefore [`Histogram::mean`]) are
/// exact; [`Histogram::quantile`] and [`Histogram::cdf`] report bucket
/// upper edges (≤ 1/128 relative error), clamped to the observed
/// `[min, max]` like the pre-PR-5 implementation.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a fixed-point µs value: `leading_zeros` picks the
    /// log2 segment, the top `SUB_BITS` mantissa bits the sub-bucket.
    #[inline]
    fn index_us(x: u64) -> usize {
        if x < SUB_BUCKETS as u64 {
            return x as usize;
        }
        let msb = 63 - x.leading_zeros(); // >= SUB_BITS
        let seg = (msb - SUB_BITS + 1) as usize;
        let sub = ((x >> (msb - SUB_BITS)) & (SUB_BUCKETS as u64 - 1)) as usize;
        (seg << SUB_BITS) + sub
    }

    /// Exclusive upper edge of bucket `i`, as f64 (reporting only).
    fn bucket_high(i: usize) -> f64 {
        let seg = i >> SUB_BITS;
        let sub = (i & (SUB_BUCKETS - 1)) as u128;
        if seg == 0 {
            (sub + 1) as f64
        } else {
            // u128 shift: the top segment's edge (256 << 56) overflows u64.
            ((SUB_BUCKETS as u128 + sub + 1) << (seg - 1)) as f64
        }
    }

    /// Integer fast path: the per-op record is a few ALU ops and two
    /// array updates (the drivers feed µs latencies directly).
    #[inline]
    pub fn record_us(&mut self, us: u64) {
        self.buckets[Self::index_us(us)] += 1;
        self.count += 1;
        let v = us as f64;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Float shim (tests/figures): truncates to fixed-point µs for
    /// bucketing while keeping `sum`/`min`/`max` exact in f64.
    pub fn record(&mut self, v: f64) {
        debug_assert!(v.is_finite() && v >= 0.0, "bad sample {v}");
        let idx = Self::index_us(v.max(0.0) as u64);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.buckets.len(), other.buckets.len());
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of recorded values as integer µs. `sum` accumulates
    /// integer µs in f64, which is exact below 2^53 — far beyond any
    /// simulated run's total latency.
    pub fn sum_us(&self) -> u64 {
        self.sum as u64
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Approximate quantile `q` in [0,1] (bucket upper edge).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let edge = Self::bucket_high(i);
                return edge.min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Order-sensitive digest of the full histogram state (bucket counts,
    /// count, sum/min/max bit patterns) — used by the determinism
    /// regression tests to compare two runs exactly.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fasthash::FnvHasher::default();
        use std::hash::Hasher;
        h.write_u64(self.count);
        h.write_u64(self.sum.to_bits());
        h.write_u64(self.min.to_bits());
        h.write_u64(self.max.to_bits());
        for (i, &c) in self.buckets.iter().enumerate() {
            if c != 0 {
                h.write_usize(i);
                h.write_u64(c);
            }
        }
        h.finish()
    }

    /// CDF as `(value, cumulative_fraction)` points over non-empty buckets —
    /// directly plottable as the paper's Figure 10.
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::new();
        if self.count == 0 {
            return out;
        }
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            acc += c;
            out.push((Self::bucket_high(i).min(self.max), acc as f64 / self.count as f64));
        }
        out
    }
}

/// The pre-PR-5 log-bucketed histogram (`ln` per record), retained
/// verbatim as the differential baseline for the `hist` bench hot spot
/// and the resolution-equivalence tests.
pub mod reference {
    /// Log-bucketed histogram over positive values (typically µs).
    #[derive(Clone, Debug)]
    pub struct LnHistogram {
        /// buckets[i] counts values in [lo * G^i, lo * G^(i+1)).
        buckets: Vec<u64>,
        lo: f64,
        growth: f64,
        inv_log_growth: f64,
        count: u64,
        sum: f64,
        min: f64,
        max: f64,
    }

    impl LnHistogram {
        /// 1 µs .. ~17 minutes at 1% resolution.
        pub fn new() -> Self {
            Self::with_range(1.0, 1.01, 2200)
        }

        pub fn with_range(lo: f64, growth: f64, n_buckets: usize) -> Self {
            assert!(lo > 0.0 && growth > 1.0 && n_buckets > 0);
            LnHistogram {
                buckets: vec![0; n_buckets],
                lo,
                growth,
                inv_log_growth: 1.0 / growth.ln(),
                count: 0,
                sum: 0.0,
                min: f64::INFINITY,
                max: f64::NEG_INFINITY,
            }
        }

        #[inline]
        fn index(&self, v: f64) -> usize {
            if v <= self.lo {
                return 0;
            }
            let i = ((v / self.lo).ln() * self.inv_log_growth) as usize;
            i.min(self.buckets.len() - 1)
        }

        pub fn record(&mut self, v: f64) {
            debug_assert!(v.is_finite() && v >= 0.0, "bad sample {v}");
            let idx = self.index(v.max(0.0));
            self.buckets[idx] += 1;
            self.count += 1;
            self.sum += v;
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }

        pub fn count(&self) -> u64 {
            self.count
        }

        pub fn mean(&self) -> f64 {
            if self.count == 0 {
                0.0
            } else {
                self.sum / self.count as f64
            }
        }

        /// Approximate quantile `q` in [0,1] (bucket upper edge).
        pub fn quantile(&self, q: f64) -> f64 {
            if self.count == 0 {
                return 0.0;
            }
            let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
            let mut acc = 0u64;
            for (i, &c) in self.buckets.iter().enumerate() {
                acc += c;
                if acc >= target {
                    let edge = self.lo * self.growth.powi(i as i32 + 1);
                    return edge.min(self.max).max(self.min);
                }
            }
            self.max
        }

        pub fn p50(&self) -> f64 {
            self.quantile(0.50)
        }

        pub fn p99(&self) -> f64 {
            self.quantile(0.99)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert!(h.cdf().is_empty());
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in [10.0, 20.0, 30.0] {
            h.record(v);
        }
        assert!((h.mean() - 20.0).abs() < 1e-9);
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 10.0);
        assert_eq!(h.max(), 30.0);
    }

    #[test]
    fn quantiles_within_resolution() {
        let mut h = Histogram::new();
        for i in 1..=10_000 {
            h.record(i as f64);
        }
        let p50 = h.p50();
        let p99 = h.p99();
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.03, "p50={p50}");
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.03, "p99={p99}");
    }

    #[test]
    fn cdf_monotone_and_complete() {
        let mut h = Histogram::new();
        for i in 0..1000 {
            h.record((i % 97) as f64 + 1.0);
        }
        let cdf = h.cdf();
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0, "values monotone");
            assert!(w[0].1 <= w[1].1, "fractions monotone");
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for i in 0..500 {
            let v = (i as f64) * 3.7 + 1.0;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert!((a.mean() - c.mean()).abs() < 1e-9);
        assert_eq!(a.p50(), c.p50());
    }

    #[test]
    fn quantile_extremes() {
        let mut h = Histogram::new();
        for v in [5.0, 500.0, 50_000.0] {
            h.record(v);
        }
        assert!(h.quantile(0.0) <= h.quantile(1.0));
        assert!(h.quantile(1.0) >= 50_000.0 * 0.98);
    }

    #[test]
    fn index_is_monotone_and_continuous() {
        // Exact linear region.
        for x in 0..SUB_BUCKETS as u64 {
            assert_eq!(Histogram::index_us(x), x as usize);
        }
        // Monotone (non-decreasing) across segment boundaries, and every
        // bucket's upper edge bounds the values it receives.
        let mut prev = 0usize;
        for shift in 0..57u32 {
            for off in [0u64, 1, 63, 64, 127] {
                let x = (SUB_BUCKETS as u64 + off) << shift;
                let i = Histogram::index_us(x);
                assert!(i >= prev, "index not monotone at {x}");
                assert!(Histogram::bucket_high(i) > x as f64, "edge bounds value at {x}");
                prev = i;
            }
        }
        assert!(Histogram::index_us(u64::MAX) < N_BUCKETS);
    }

    #[test]
    fn record_us_matches_record_on_integers() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut x = 1u64;
        for _ in 0..64 {
            a.record_us(x);
            b.record(x as f64);
            x = x.wrapping_mul(2862933555777941757).wrapping_add(3037000493) >> 20;
        }
        assert_eq!(a.fingerprint(), b.fingerprint(), "integer and float paths agree");
    }

    #[test]
    fn resolution_matches_reference_quantiles() {
        // The integer-bucketed path reports the same quantiles as the
        // retained ln-bucketed reference within combined resolution.
        let mut cur = Histogram::new();
        let mut refh = reference::LnHistogram::with_range(1.0, 1.01, 2200);
        let mut v = 1.0f64;
        for i in 0..20_000 {
            let x = 1.0 + (v * 100_000.0) % 250_000.0;
            cur.record_us(x as u64);
            refh.record((x as u64) as f64);
            v = (v * 1.0000931 + i as f64 * 1e-5) % 1.0 + 1.0;
        }
        assert_eq!(cur.count(), refh.count());
        assert!((cur.mean() - refh.mean()).abs() / refh.mean() < 1e-9);
        for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
            let a = cur.quantile(q);
            let b = refh.quantile(q);
            assert!((a - b).abs() / b.max(1.0) < 0.03, "q={q}: {a} vs {b}");
        }
    }
}
