//! A TOML-subset parser for λFS config files.
//!
//! Supports: `[section]` headers, `key = value` with string / integer /
//! float / boolean values, comments (`#`), and blank lines — the subset the
//! λFS config format (`config::SystemConfig::from_toml`) needs. The
//! `serde`/`toml` crates are not in the offline vendored set.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`workers = 4` reads as 4.0).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line number context.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "minitoml: line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parsed document: `section.key -> value`. Keys outside any section live
/// under the empty section `""`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: ln + 1,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(ParseError { line: ln + 1, msg: "empty section name".into() });
                }
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: ln + 1,
                msg: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(ParseError { line: ln + 1, msg: "empty key".into() });
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|msg| ParseError { line: ln + 1, msg })?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, val);
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_i64)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(unescape(inner)?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('\\') => out.push('\\'),
            Some('"') => out.push('"'),
            other => return Err(format!("bad escape \\{other:?}")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
            # top comment
            top = 1
            [faas]
            cold_start_ms = 900.5
            warm = true
            name = "openwhisk"  # trailing comment
            [store]
            data_nodes = 4
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_i64("top"), Some(1));
        assert_eq!(doc.get_f64("faas.cold_start_ms"), Some(900.5));
        assert_eq!(doc.get_bool("faas.warm"), Some(true));
        assert_eq!(doc.get_str("faas.name"), Some("openwhisk"));
        assert_eq!(doc.get_i64("store.data_nodes"), Some(4));
        assert_eq!(doc.len(), 5);
    }

    #[test]
    fn int_readable_as_float() {
        let doc = Doc::parse("x = 4").unwrap();
        assert_eq!(doc.get_f64("x"), Some(4.0));
    }

    #[test]
    fn underscores_in_numbers() {
        let doc = Doc::parse("ops = 25_000").unwrap();
        assert_eq!(doc.get_i64("ops"), Some(25000));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Doc::parse(r##"s = "a#b""##).unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn escapes() {
        let doc = Doc::parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc.get_str("s"), Some("a\nb\t\"c\""));
    }

    #[test]
    fn error_reports_line() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn unterminated_section_rejected() {
        assert!(Doc::parse("[faas").is_err());
    }

    #[test]
    fn unterminated_string_rejected() {
        assert!(Doc::parse(r#"s = "abc"#).is_err());
    }

    #[test]
    fn missing_key_is_none() {
        let doc = Doc::parse("a = 1").unwrap();
        assert!(doc.get("nope").is_none());
        assert!(doc.get_f64("nope").is_none());
    }

    #[test]
    fn type_mismatch_is_none() {
        let doc = Doc::parse("a = \"str\"").unwrap();
        assert!(doc.get_i64("a").is_none());
        assert!(doc.get_bool("a").is_none());
        assert_eq!(doc.get_str("a"), Some("str"));
    }
}
