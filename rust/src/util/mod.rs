//! Small self-contained utilities.
//!
//! The offline vendored crate set only contains the `xla` crate's
//! dependency closure, so the usual ecosystem crates (`rand`, `serde`,
//! `clap`, `proptest`, `criterion`) are re-implemented here at the scale
//! this project needs:
//!
//! * [`rng`] — SplitMix64-seeded xoshiro256** PRNG.
//! * [`dist`] — table-driven Pareto / Zipf / exponential / log-normal /
//!   normal samplers (quantile LUTs + alias tables; one `u64` draw per
//!   sample, no transcendental math after construction) with the
//!   closed-form originals retained under `dist::reference`.
//! * [`fnv`] — FNV-1a 32-bit, bit-identical to the L1 Pallas kernel.
//! * [`fasthash`] — FNV-backed `FxHashMap`-style hasher for the hot-path
//!   maps (deterministic, one multiply per interned-id key).
//! * [`hist`] — integer-bucketed latency histogram (log2 segments +
//!   linear sub-buckets; no `ln` per record) with exact-ish percentiles
//!   and CDFs.
//! * [`minitoml`] — a TOML-subset parser for config files.
//! * [`cli`] — flag/option argument parsing for the `lambdafs` binary.
//! * [`ptest`] — a miniature property-testing harness (seeded generators,
//!   iteration control, failure reporting).

pub mod cli;
pub mod dist;
pub mod fasthash;
pub mod fnv;
pub mod hist;
pub mod minitoml;
pub mod ptest;
pub mod rng;
