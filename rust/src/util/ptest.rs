//! A miniature property-testing harness.
//!
//! The `proptest` crate is not in the offline vendored set, so this module
//! provides the slice of it the test suite uses: seeded generators, a
//! configurable iteration count, and failure reporting that prints the seed
//! and iteration so a failing case can be replayed deterministically.
//!
//! ```ignore
//! ptest::check("routing is stable", 500, |g| {
//!     let path = g.path(6);
//!     let n = g.int(1, 32) as u32;
//!     ptest::ensure(fnv::route(&path, n) < n, "route in range")
//! });
//! ```

use super::rng::Rng;

/// Outcome of one property evaluation.
pub type PropResult = Result<(), String>;

/// Convenience assertion returning a `PropResult`.
pub fn ensure(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Equality assertion with value reporting.
pub fn ensure_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, msg: &str) -> PropResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{msg}: {a:?} != {b:?}"))
    }
}

/// Generator handed to each property iteration.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Random lowercase identifier of length `1..=max_len`.
    pub fn ident(&mut self, max_len: usize) -> String {
        let len = 1 + self.rng.below(max_len.max(1) as u64) as usize;
        (0..len).map(|_| (b'a' + self.rng.below(26) as u8) as char).collect()
    }

    /// Random absolute path with `1..=max_depth` components.
    pub fn path(&mut self, max_depth: usize) -> String {
        let depth = 1 + self.rng.below(max_depth.max(1) as u64) as usize;
        let mut p = String::new();
        for _ in 0..depth {
            p.push('/');
            p.push_str(&self.ident(8));
        }
        p
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.pick(xs)
    }

    /// A vector of `0..=max_len` elements built by `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.rng.below(max_len as u64 + 1) as usize;
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `iters` iterations of `prop`, seeded from `PTEST_SEED` (env) or a
/// fixed default. Panics with seed/iteration context on the first failure.
pub fn check(name: &str, iters: u64, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    let seed = std::env::var("PTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDA7A_5EED_u64);
    let mut root = Rng::new(seed);
    for it in 0..iters {
        let mut g = Gen { rng: root.fork(&format!("{name}:{it}")) };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed at iteration {it} (seed {seed:#x}): {msg}\n\
                 replay with PTEST_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_iterations() {
        let mut count = 0;
        check("trivial", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_panics_with_context() {
        check("fails", 10, |g| ensure(g.int(0, 9) < 5, "too big"));
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 200, |g| {
            let i = g.int(-5, 5);
            ensure(( -5..=5).contains(&i), "int bounds")?;
            let f = g.f64(1.0, 2.0);
            ensure((1.0..2.0).contains(&f), "f64 bounds")?;
            let p = g.path(4);
            ensure(p.starts_with('/'), "path absolute")?;
            ensure(p.split('/').skip(1).count() <= 4, "path depth")?;
            let v = g.vec(7, |g| g.bool());
            ensure(v.len() <= 7, "vec len")
        });
    }

    #[test]
    fn deterministic_given_same_seed() {
        let mut a = Vec::new();
        check("det", 20, |g| {
            a.push(g.int(0, 1_000_000));
            Ok(())
        });
        let mut b = Vec::new();
        check("det", 20, |g| {
            b.push(g.int(0, 1_000_000));
            Ok(())
        });
        assert_eq!(a, b);
    }
}
