//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component of the simulation draws from its own named
//! stream (`Rng::fork`) so that adding a component never perturbs the
//! sequence another component sees — the property that makes whole-figure
//! simulations reproducible bit-for-bit across runs and refactors.

/// xoshiro256** 1.0 (Blackman & Vigna), seeded with SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a 64-bit value (SplitMix64-expanded to the 256-bit state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named component.
    ///
    /// The label is FNV-hashed into the fork seed, so
    /// `rng.fork("clients")` and `rng.fork("store")` are decorrelated and
    /// stable across code changes that add/remove other forks.
    pub fn fork(&mut self, label: &str) -> Rng {
        let h = crate::util::fnv::fnv1a64(label.as_bytes());
        Rng::new(self.next_u64() ^ h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)` (f64).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn forked_streams_are_decorrelated() {
        let mut root = Rng::new(7);
        let mut a = root.fork("clients");
        let mut b = root.fork("store");
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same <= 1);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn uniformity_rough_chi_square() {
        let mut r = Rng::new(5);
        let mut buckets = [0u32; 16];
        let n = 160_000;
        for _ in 0..n {
            buckets[r.below(16) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        let chi2: f64 = buckets
            .iter()
            .map(|&c| {
                let d = c as f64 - expect;
                d * d / expect
            })
            .sum();
        // 15 dof, p=0.001 critical value ≈ 37.7.
        assert!(chi2 < 37.7, "chi2 = {chi2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut r = Rng::new(8);
        assert!((0..1000).all(|_| !r.chance(0.0)));
        assert!((0..1000).all(|_| r.chance(1.0)));
    }
}
