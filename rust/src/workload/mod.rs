//! Workload generators and the workload catalog.
//!
//! Every workload the repository can drive, its origin, and how to run
//! it. Workloads in the first group are generated live by the drivers in
//! [`crate::systems::driver`]; those in the second are produced (or
//! captured) as traces and executed through [`crate::trace::replay`],
//! which feeds λFS and every baseline the identical op stream.
//!
//! **Paper-figure workloads (generated live):**
//!
//! | workload | origin | invocation |
//! |---|---|---|
//! | Spotify op mix, Pareto-bursty open loop | §5.2, Table 2, Fig. 8–10 | `lambdafs spotify`, `lambdafs figure 8a` |
//! | single-op closed-loop micro-benchmarks | §5.3, Fig. 11–13 | `lambdafs micro --op read --clients 256` |
//! | auto-scaling ablation | §5.2.4, Fig. 14 | `lambdafs figure 14` |
//! | fault-injection Spotify run | §5.6, Fig. 15 | `lambdafs figure 15` |
//! | IndexFS `tree-test` (mknod then getattr) | §5.7, Fig. 16 | `lambdafs figure 16` |
//! | subtree mv/delete | Appendix C, Table 3 | `lambdafs subtree --files 262144` |
//!
//! **Trace-engine workloads (new scenario classes, beyond the paper):**
//!
//! | workload | origin | invocation |
//! |---|---|---|
//! | recorded replay of any run above | `crate::trace::Recorder` | `lambdafs scenario`, `cargo run --example trace_replay` |
//! | ML-training pipeline (epoch-structured hot-dir reads + checkpoint bursts) | FalconFS-style, `crate::trace::synth::ml_pipeline` | `lambdafs scenario` |
//! | container-platform churn (deep-path create/stat/unlink, Pareto bursts) | CFS-style, `crate::trace::synth::container_churn` | `lambdafs scenario` |
//!
//! The scenario matrix sweeps (system × workload × scale) and writes
//! `SCENARIOS.json`; see [`crate::trace::scenario`]. Since the
//! outcome-bearing `MetadataService` migration, every cell also carries
//! per-op outcome columns folded from the `Completion` stream —
//! `cold_starts`, `warm_ops`, `cache_hits`, `cache_misses`,
//! `cache_hit_ratio`, `retries` — conserved per cell
//! (`cold_starts + warm_ops == completed_ops`) and validated by the CI
//! schema check. Figures gain the same columns via
//! [`crate::figures::common::outcome_cells`].
//!
//! **Chaos axis (schema v3):** the matrix additionally replays the
//! Spotify trace under each seeded fault plan in
//! [`crate::trace::scenario::CHAOS_MODES`] — `kills` (round-robin
//! instance kills, the generalized Fig. 15 schedule), `partition`
//! (client-VM↔deployment legs severed until the end of the run), and
//! `delay-storm` (degraded links + straggler burst + a short deployment
//! blackout) — against every system. Chaos cells carry `timeouts` and
//! `gave_up` columns with the conservation law
//! `completed_ops + gave_up == submitted`; plans are declarative
//! [`crate::chaos::ChaosPlan`]s that ride in the trace header, so any
//! recorded chaotic run replays bit-identically (pinned in
//! `rust/tests/determinism.rs`).

pub mod schedule;
pub mod spec;
pub mod spotify;

pub use schedule::ThroughputSchedule;
pub use spec::{ClosedLoopSpec, OpenLoopSpec};
pub use spotify::OpMix;
