//! Workload generators and the workload catalog.
//!
//! Every workload the repository can drive, its origin, and how to run
//! it. Workloads in the first group are generated live by the drivers in
//! [`crate::systems::driver`]; those in the second are produced (or
//! captured) as traces and executed through [`crate::trace::replay`],
//! which feeds λFS and every baseline the identical op stream.
//!
//! **Paper-figure workloads (generated live):**
//!
//! | workload | origin | invocation |
//! |---|---|---|
//! | Spotify op mix, Pareto-bursty open loop | §5.2, Table 2, Fig. 8–10 | `lambdafs spotify`, `lambdafs figure 8a` |
//! | single-op closed-loop micro-benchmarks | §5.3, Fig. 11–13 | `lambdafs micro --op read --clients 256` |
//! | auto-scaling ablation | §5.2.4, Fig. 14 | `lambdafs figure 14` |
//! | fault-injection Spotify run | §5.6, Fig. 15 | `lambdafs figure 15` |
//! | IndexFS `tree-test` (mknod then getattr) | §5.7, Fig. 16 | `lambdafs figure 16` |
//! | subtree mv/delete | Appendix C, Table 3 | `lambdafs subtree --files 262144` |
//!
//! **Trace-engine workloads (new scenario classes, beyond the paper):**
//!
//! | workload | origin | invocation |
//! |---|---|---|
//! | recorded replay of any run above | `crate::trace::Recorder` | `lambdafs scenario`, `cargo run --example trace_replay` |
//! | ML-training pipeline (epoch-structured hot-dir reads + checkpoint bursts) | FalconFS-style, `crate::trace::synth::ml_pipeline` | `lambdafs scenario` |
//! | container-platform churn (deep-path create/stat/unlink, Pareto bursts) | CFS-style, `crate::trace::synth::container_churn` | `lambdafs scenario` |
//! | directory reorganization (live-half file churn + archive-half subtree mv/delete) | crash-recovery stressor, `crate::trace::synth::dir_reorg` | `lambdafs scenario` |
//!
//! The scenario matrix sweeps (system × workload × scale) and writes
//! `SCENARIOS.json`; see [`crate::trace::scenario`]. Since the
//! outcome-bearing `MetadataService` migration, every cell also carries
//! per-op outcome columns folded from the `Completion` stream —
//! `cold_starts`, `warm_ops`, `cache_hits`, `cache_misses`,
//! `cache_hit_ratio`, `retries` — conserved per cell
//! (`cold_starts + warm_ops == completed_ops`) and validated by the CI
//! schema check. Figures gain the same columns via
//! [`crate::figures::common::outcome_cells`].
//!
//! **Chaos axis (schema v3):** the matrix additionally replays the
//! Spotify trace under each seeded fault plan in
//! [`crate::trace::scenario::CHAOS_MODES`] — `kills` (round-robin
//! instance kills, the generalized Fig. 15 schedule), `partition`
//! (client-VM↔deployment legs severed until the end of the run), and
//! `delay-storm` (degraded links + straggler burst + a short deployment
//! blackout) — against every system. Chaos cells carry `timeouts` and
//! `gave_up` columns with the conservation law
//! `completed_ops + gave_up == submitted`; plans are declarative
//! [`crate::chaos::ChaosPlan`]s that ride in the trace header, so any
//! recorded chaotic run replays bit-identically (pinned in
//! `rust/tests/determinism.rs`).
//!
//! **Crash-recovery axis (schema v7):** the matrix replays the
//! dir-reorg trace under `kill-storm` — a kill in every one of the
//! first four deployments at every second boundary plus
//! invalidation-ack chaos — against every system. Wide subtree serve
//! windows crossing per-second kill boundaries guarantee orphaned ops,
//! so λFS kill-storm cells must show the recovery machinery firing.
//! Every cell (any chaos) carries five recovery columns —
//! `orphaned_ops`, `recovered_ops`, `aborted_ops`, `locks_reclaimed`,
//! `audit_violations` — with the intent-conservation law
//! `orphaned_ops == recovered_ops + aborted_ops` and a hard
//! `audit_violations == 0` gate enforced by the CI validator. See
//! `docs/RECOVERY.md` for the protocol and the auditor's invariant
//! catalogue, and `rust/tests/chaos_properties.rs` for the randomized
//! fault-plan property sweep.
//!
//! **Provisioning-policy axis (schema v6):** the matrix additionally
//! runs the bursty workloads (ml-pipeline, container-churn) against
//! λFS under each mode in [`crate::trace::scenario::POLICY_MODES`] —
//! `pooled-restore` (the cold-start tier ladder on: warm-pool hits
//! ~5 ms, checkpoint restores ~50 ms, ephemeral boots ~180 ms, reactive
//! scale-out) and `predictive` (ladder plus EWMA per-deployment arrival
//! forecasting pre-booting into the pool, `crate::scaling::predict`).
//! Every cell carries a `policy` tag plus per-tier cold-start columns
//! (`pool_hits`, `restores`, `ephemeral_boots`) conserved against
//! `cold_starts`; plain cells are tagged `reactive` and keep the binary
//! cold-start model (both rungs zero). Figure 14b
//! (`fig14_policy.csv`) ablates the three modes on the Read workload.
//!
//! # Scale tiers
//!
//! The matrix (and the Spotify figure driver) runs at one of four
//! scale tiers. The first three differ only in `--scale` / `--smoke`;
//! the mega tier additionally requires the sharded engine
//! ([`crate::sim::shard`]), because a 10⁶-client fleet is impractical
//! on the single-threaded event loop.
//!
//! | tier | scale axis | invocation | engine |
//! |---|---|---|---|
//! | smoke | 0.01, single scale | `lambdafs scenario --smoke` | sequential (CI runs this) |
//! | default | 0.05 plus a 2× step | `lambdafs scenario` | sequential |
//! | full | 1.0 (paper-scale fleets) | `lambdafs scenario --scale 1.0` | sequential or sharded |
//! | mega | 10⁶-client mega-fleet workload | `lambdafs scenario --shards 8` (non-smoke) | sharded, required |
//!
//! `--shards N` (N > 1) runs *every* cell on the conservative
//! time-window engine and records per-cell `shards` / `wall_s` columns
//! (since schema v5); the mega-fleet tier is appended only to non-smoke
//! sharded runs. Sharded cells are their own fingerprint domain — see
//! the artifact-comparability note in `ROADMAP.md`. The default
//! `--shards 1` path is byte-identical to pre-sharding runs.
//!
//! # Reading a Perfetto trace
//!
//! `lambdafs observe [--smoke] [--storm] [--out trace.json]` runs the
//! Spotify workload against λFS with the per-second timeline sampler
//! armed and a small seeded fault schedule installed (two instance
//! kills plus one deployment blackout, placed at fixed fractions of
//! the run), then writes the run as Chrome trace-event JSON.
//! `--storm` swaps in the dir-reorg workload under the kill-storm
//! fault plan, so the crash-recovery machinery is visibly load-bearing
//! in the trace. Load the file at <https://ui.perfetto.dev> (or
//! `chrome://tracing`); one trace second equals one sampled simulation
//! second.
//!
//! Nine counter tracks render the sampler's gauges:
//!
//! | track | meaning |
//! |---|---|
//! | `live instances` | serverless instances per deployment (stacked series `dep0`, `dep1`, …) — watch it dip at a kill and refill as the scheduler scales back out |
//! | `warm instances` | instances past cold-start and reusable; the gap to `live instances` is capacity still paying cold-start |
//! | `warm pool (instances)` | tier-ladder warm-pool occupancy (pre-booted, not yet serving); flat zero unless `faas.tier_ladder` is on — predictive prewarming shows as the pool filling *before* a burst's `scale-out` instants |
//! | `throughput (ops/s)` | completed ops in each sampled second |
//! | `backlog (ops)` | submitted-but-not-completed ops; growth means the offered load outruns capacity |
//! | `cache hit ratio (%)` | metadata-cache hit rate over the ops completed that second |
//! | `cost rate ($/s)` | simulated spend rate (the cost model's running total, differenced per second) |
//! | `faults (cumulative)` | running count of timeouts + give-ups; flat means the fault schedule isn't biting |
//! | `recovered ops (cumulative)` | running count of orphaned ops replayed with a late ack; steps up one recovery lease after each kill boundary |
//!
//! Instant events (grey vertical carets, global scope) mark the fault
//! schedule and the platform's reaction: `kill` for each scheduled
//! instance kill, `recovery sweep` one lease after each kill boundary
//! (the moment the reclamation protocol replays-or-aborts the dead
//! instance's open intents and releases its stranded locks),
//! `blackout start` / `blackout end` bracketing a deployment blackout,
//! and `scale-out` when the platform adds instances. Correlating an
//! instant with the counter tracks around it is the intended reading:
//! a `kill` should show `live instances` dropping, `backlog (ops)`
//! bumping, and `throughput (ops/s)` recovering within a few seconds.
//!
//! Beside `traceEvents`, the artifact carries a `lambdafs` summary
//! section (schema `lambdafs-trace-events-v2`) holding the span layer's
//! phase ledger — per-phase latency totals and p50/p99 for the seven
//! phases (`queue`, `cold`, `net`, `exec`, `coherence`, `store`,
//! `retry`), the dominant phase, and the end-to-end total — plus the
//! crash-recovery ledger (`orphaned_ops`, `recovered_ops`,
//! `aborted_ops`, `locks_reclaimed`, `audit_violations`,
//! `recovery_lease_us`). Both ledgers conserve:
//! `sum(phase_totals_us) == e2e_total_us` (the span cursor attributes
//! every microsecond of every completed op to exactly one phase) and
//! `orphaned_ops == recovered_ops + aborted_ops` (the intent log never
//! loses an orphan). `scripts/validate_trace_events.py` (run by CI on
//! both smoke artifacts, the storm one with `--expect-orphans`)
//! rejects any trace that violates either law, reports auditor
//! violations, has non-monotone timestamps, is missing a counter
//! track, or whose `recovery sweep` instants don't sit exactly one
//! lease past their kill boundaries.

pub mod schedule;
pub mod spec;
pub mod spotify;

pub use schedule::ThroughputSchedule;
pub use spec::{ClosedLoopSpec, OpenLoopSpec};
pub use spotify::OpMix;
