//! Workload generators: the Spotify industrial workload (§5.2), the
//! scaling micro-benchmarks (§5.3), IndexFS' `tree-test` (§5.7), and the
//! subtree workload (Table 3).

pub mod schedule;
pub mod spec;
pub mod spotify;

pub use schedule::ThroughputSchedule;
pub use spec::{ClosedLoopSpec, OpenLoopSpec};
pub use spotify::OpMix;
