//! The bursty throughput schedule (§5.2.1).
//!
//! Every 15 seconds the benchmark draws a target throughput Δ from a
//! Pareto distribution with shape α=2 and scale `x_t` (the workload's
//! base throughput), clamped at 7× base — "the benchmark randomly
//! generates throughput spikes up to 7× greater than the base". Each
//! client VM then attempts to sustain δ = Δ/n ops/sec, with unfinished
//! operations rolling over to the next second.
//!
//! The redraws sample the table-driven `Pareto` (quantile LUT — see
//! `util::dist`); the exact inverse-CDF formula the LUT is built from is
//! retained in `util::dist::reference::Pareto` and matches the
//! AOT-lowered `pareto_schedule` artifact, which the runtime test
//! cross-checks against the formula directly.

use crate::sim::{time, Time};
use crate::util::dist::Pareto;
use crate::util::rng::Rng;

/// Per-second target throughput over a workload.
#[derive(Clone, Debug)]
pub struct ThroughputSchedule {
    /// Target total ops/sec for each second of the run.
    per_second: Vec<f64>,
}

impl ThroughputSchedule {
    /// The paper's schedule: `duration` seconds, redrawing every
    /// `interval` seconds from Pareto(x_t, alpha) clamped at `burst_cap`×x_t.
    pub fn pareto_bursty(
        duration_s: usize,
        interval_s: usize,
        x_t: f64,
        alpha: f64,
        burst_cap: f64,
        rng: &mut Rng,
    ) -> Self {
        let p = Pareto::new(x_t, alpha);
        let mut per_second = Vec::with_capacity(duration_s);
        let mut current = x_t;
        for s in 0..duration_s {
            if s % interval_s.max(1) == 0 {
                current = p.sample_capped(rng, burst_cap * x_t);
            }
            per_second.push(current);
        }
        ThroughputSchedule { per_second }
    }

    /// Constant-rate schedule.
    pub fn constant(duration_s: usize, ops_per_sec: f64) -> Self {
        ThroughputSchedule { per_second: vec![ops_per_sec; duration_s] }
    }

    /// Inject a deterministic burst (used by tests and the paper-shaped
    /// fixture where the 7× spike lands around t=200s).
    pub fn with_burst(mut self, start_s: usize, len_s: usize, ops_per_sec: f64) -> Self {
        for s in start_s..(start_s + len_s).min(self.per_second.len()) {
            self.per_second[s] = ops_per_sec;
        }
        self
    }

    pub fn duration_s(&self) -> usize {
        self.per_second.len()
    }

    pub fn duration(&self) -> Time {
        self.per_second.len() as Time * time::SEC
    }

    /// Target for second `s`.
    pub fn target(&self, s: usize) -> f64 {
        self.per_second.get(s).copied().unwrap_or(0.0)
    }

    pub fn peak(&self) -> f64 {
        self.per_second.iter().copied().fold(0.0, f64::max)
    }

    pub fn total_ops(&self) -> f64 {
        self.per_second.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_schedule_bounds() {
        let mut rng = Rng::new(55);
        let s = ThroughputSchedule::pareto_bursty(300, 15, 25_000.0, 2.0, 7.0, &mut rng);
        assert_eq!(s.duration_s(), 300);
        for i in 0..300 {
            let t = s.target(i);
            assert!(t >= 25_000.0, "never below base");
            assert!(t <= 7.0 * 25_000.0, "clamped at 7x");
        }
    }

    #[test]
    fn redraw_interval_is_15s() {
        let mut rng = Rng::new(56);
        let s = ThroughputSchedule::pareto_bursty(60, 15, 25_000.0, 2.0, 7.0, &mut rng);
        for block in 0..4 {
            let first = s.target(block * 15);
            for i in 1..15 {
                assert_eq!(s.target(block * 15 + i), first, "constant within interval");
            }
        }
    }

    #[test]
    fn bursts_actually_occur() {
        let mut rng = Rng::new(57);
        let s = ThroughputSchedule::pareto_bursty(300, 15, 25_000.0, 2.0, 7.0, &mut rng);
        assert!(s.peak() > 40_000.0, "some spike above 1.6x base: {}", s.peak());
    }

    #[test]
    fn with_burst_injection() {
        let s = ThroughputSchedule::constant(300, 25_000.0).with_burst(200, 15, 163_996.0);
        assert_eq!(s.target(199), 25_000.0);
        assert_eq!(s.target(200), 163_996.0);
        assert_eq!(s.target(214), 163_996.0);
        assert_eq!(s.target(215), 25_000.0);
        assert_eq!(s.peak(), 163_996.0);
    }

    #[test]
    fn out_of_range_target_is_zero() {
        let s = ThroughputSchedule::constant(10, 100.0);
        assert_eq!(s.target(10), 0.0);
        assert_eq!(s.duration(), 10 * time::SEC);
    }
}
