//! Workload specifications shared by every system driver.

use crate::namespace::generate::NamespaceParams;
use crate::namespace::OpKind;

use super::schedule::ThroughputSchedule;
use super::spotify::OpMix;

/// Open-loop workload: a throughput schedule drives op generation
/// (the Spotify workload, §5.2).
#[derive(Clone, Debug)]
pub struct OpenLoopSpec {
    pub schedule: ThroughputSchedule,
    pub mix: OpMix,
    /// Total client processes (paper: 1,024).
    pub n_clients: u32,
    /// Client VMs (paper: 8); TCP connection sharing is per-VM.
    pub n_vms: u32,
    pub namespace: NamespaceParams,
    /// Hot-directory skew.
    pub zipf_s: f64,
}

impl OpenLoopSpec {
    /// The paper's Spotify workload at base throughput `x_t` for
    /// `duration_s` seconds.
    pub fn spotify(x_t: f64, duration_s: usize, rng: &mut crate::util::rng::Rng) -> Self {
        OpenLoopSpec {
            schedule: ThroughputSchedule::pareto_bursty(duration_s, 15, x_t, 2.0, 7.0, rng),
            mix: OpMix::spotify(),
            n_clients: 1024,
            n_vms: 8,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        }
    }
}

/// Closed-loop workload: each client performs `ops_per_client` operations
/// back-to-back (the §5.3 micro-benchmarks: 3,072 ops per client).
#[derive(Clone, Debug)]
pub struct ClosedLoopSpec {
    pub kind: OpKind,
    pub n_clients: u32,
    pub n_vms: u32,
    pub ops_per_client: u32,
    pub namespace: NamespaceParams,
    pub zipf_s: f64,
}

impl ClosedLoopSpec {
    /// The paper's client-driven-scaling configuration.
    pub fn micro(kind: OpKind, n_clients: u32) -> Self {
        ClosedLoopSpec {
            kind,
            n_clients,
            n_vms: (n_clients / 128).clamp(1, 8),
            ops_per_client: 3_072,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        }
    }

    pub fn total_ops(&self) -> u64 {
        self.n_clients as u64 * self.ops_per_client as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn spotify_spec_defaults() {
        let mut rng = Rng::new(1);
        let s = OpenLoopSpec::spotify(25_000.0, 300, &mut rng);
        assert_eq!(s.n_clients, 1024);
        assert_eq!(s.n_vms, 8);
        assert_eq!(s.schedule.duration_s(), 300);
        assert!((s.mix.write_fraction() - 0.0477).abs() < 1e-9);
    }

    #[test]
    fn micro_spec_scales_vms() {
        let s = ClosedLoopSpec::micro(OpKind::Read, 8);
        assert_eq!(s.n_vms, 1);
        assert_eq!(s.total_ops(), 8 * 3072);
        let s = ClosedLoopSpec::micro(OpKind::Read, 1024);
        assert_eq!(s.n_vms, 8);
    }
}
