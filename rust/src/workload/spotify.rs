//! The Spotify-workload operation mix (paper Table 2).
//!
//! Generated from statistics of Spotify's 1600-node HDFS cluster: 95.23 %
//! reads, 4.77 % writes. The mix is a categorical sampler over
//! [`OpKind`]s; op targets come from the hotspot-skewed namespace sampler.

use crate::namespace::generate::HotspotSampler;
use crate::namespace::{Namespace, OpKind, Operation};
use crate::util::dist::Alias;
use crate::util::rng::Rng;

/// A categorical distribution over operation kinds, sampled through the
/// table-driven substrate: one RNG draw and at most two table reads per
/// kind (`util::dist::Alias`), instead of a cumulative-probability scan.
#[derive(Clone, Debug)]
pub struct OpMix {
    /// Kind per alias column (index-aligned with `alias`).
    kinds: Vec<OpKind>,
    alias: Alias,
    /// Write-kind probability mass, precomputed at construction.
    write_fraction: f64,
}

impl OpMix {
    /// Paper Table 2: the Spotify workload frequencies.
    pub fn spotify() -> Self {
        OpMix::from_weights(&[
            (OpKind::Read, 0.6922),
            (OpKind::Stat, 0.17),
            (OpKind::Ls, 0.0901),
            (OpKind::Create, 0.027),
            (OpKind::Mv, 0.013),
            (OpKind::Delete, 0.0075),
            (OpKind::Mkdir, 0.0002),
        ])
    }

    /// A single-kind mix (micro-benchmarks run one op type at a time).
    pub fn only(kind: OpKind) -> Self {
        OpMix::from_weights(&[(kind, 1.0)])
    }

    /// Build from `(kind, weight)` pairs (weights need not sum to 1).
    pub fn from_weights(weights: &[(OpKind, f64)]) -> Self {
        assert!(!weights.is_empty());
        let total: f64 = weights.iter().map(|(_, w)| w).sum();
        assert!(total > 0.0);
        let kinds: Vec<OpKind> = weights.iter().map(|&(k, _)| k).collect();
        let write_fraction =
            weights.iter().filter(|(k, _)| k.is_write()).map(|(_, w)| w).sum::<f64>() / total;
        let alias = Alias::new(&weights.iter().map(|&(_, w)| w).collect::<Vec<f64>>());
        OpMix { kinds, alias, write_fraction }
    }

    /// Sample an operation kind (one draw, alias-table lookup).
    pub fn sample_kind(&self, rng: &mut Rng) -> OpKind {
        self.kinds[self.alias.sample(rng)]
    }

    /// Fraction of write-kind mass (Table 2: 4.77 % for Spotify).
    pub fn write_fraction(&self) -> f64 {
        self.write_fraction
    }

    /// Sample a full operation against a namespace.
    pub fn sample_op(&self, ns: &Namespace, sampler: &HotspotSampler, rng: &mut Rng) -> Operation {
        let kind = self.sample_kind(rng);
        match kind {
            OpKind::Mkdir => {
                Operation::single(kind, crate::namespace::InodeRef::dir(sampler.dir(rng)))
            }
            OpKind::Mv => {
                let target = sampler.inode(ns, rng);
                let dest = sampler.dir(rng);
                Operation::mv(target, dest)
            }
            OpKind::Create => {
                // Create targets a fresh file id in a sampled directory.
                let d = sampler.dir(rng);
                let fresh = ns.dir(d).files + rng.below(1 << 20) as u32;
                Operation::single(kind, crate::namespace::InodeRef::file(d, fresh))
            }
            _ => Operation::single(kind, sampler.inode(ns, rng)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::namespace::generate::{generate, NamespaceParams};

    #[test]
    fn spotify_mix_frequencies() {
        let mix = OpMix::spotify();
        let mut rng = Rng::new(8);
        let n = 500_000;
        let mut reads = 0;
        let mut creates = 0;
        let mut writes = 0;
        for _ in 0..n {
            let k = mix.sample_kind(&mut rng);
            if k == OpKind::Read {
                reads += 1;
            }
            if k == OpKind::Create {
                creates += 1;
            }
            if k.is_write() {
                writes += 1;
            }
        }
        let rf = reads as f64 / n as f64;
        let cf = creates as f64 / n as f64;
        let wf = writes as f64 / n as f64;
        assert!((rf - 0.6922).abs() < 0.005, "read {rf}");
        assert!((cf - 0.027).abs() < 0.002, "create {cf}");
        assert!((wf - 0.0477).abs() < 0.003, "write {wf} (Table 2: 4.77%)");
    }

    #[test]
    fn write_fraction_analytic() {
        assert!((OpMix::spotify().write_fraction() - 0.0477).abs() < 1e-9);
        assert_eq!(OpMix::only(OpKind::Read).write_fraction(), 0.0);
        assert_eq!(OpMix::only(OpKind::Create).write_fraction(), 1.0);
    }

    #[test]
    fn only_mix_is_pure() {
        let mix = OpMix::only(OpKind::Stat);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert_eq!(mix.sample_kind(&mut rng), OpKind::Stat);
        }
    }

    #[test]
    fn sample_op_well_formed() {
        let mut rng = Rng::new(3);
        let ns = generate(&NamespaceParams::default(), &mut rng);
        let sampler = HotspotSampler::new(&ns, 1.3, &mut rng);
        let mix = OpMix::spotify();
        for _ in 0..10_000 {
            let op = mix.sample_op(&ns, &sampler, &mut rng);
            assert!((op.target.dir.0 as usize) < ns.n_dirs());
            if op.kind == OpKind::Mv {
                assert!(op.dest.is_some());
            }
            assert!(!op.kind.is_subtree());
        }
    }
}
