//! Property test: `PathTrie` (string trie, public API) and
//! `InternedCache` (simulator fast path) implement the same cache
//! semantics — insert/lookup/exact-invalidate/subtree-invalidate agree on
//! arbitrary operation sequences over a generated namespace.

use lambda_fs::cache::interned::InternedCache;
use lambda_fs::cache::trie::PathTrie;
use lambda_fs::namespace::generate::{generate, NamespaceParams};
use lambda_fs::namespace::{DirId, InodeRef, Namespace};
use lambda_fs::util::ptest::{self, ensure, ensure_eq};

fn file_path(ns: &Namespace, inode: InodeRef) -> String {
    let dir = &ns.dir(inode.dir).path;
    match inode.file {
        Some(f) => {
            if dir == "/" {
                format!("/f{f}")
            } else {
                format!("{dir}/f{f}")
            }
        }
        None => dir.clone(),
    }
}

#[test]
fn trie_and_interned_agree_on_random_sequences() {
    let mut seed_rng = lambda_fs::util::rng::Rng::new(99);
    let ns = generate(
        &NamespaceParams { n_dirs: 64, files_per_dir: 4, max_depth: 4, zipf_s: 1.2 },
        &mut seed_rng,
    );

    ptest::check("cache equivalence", 300, |g| {
        // Capacity large enough to avoid eviction (eviction *order* is an
        // implementation detail; semantics below are about visibility).
        let mut trie: PathTrie<u64> = PathTrie::new(100_000);
        let mut interned = InternedCache::new(100_000);

        for _ in 0..g.int(1, 120) {
            let dir = DirId(g.int(0, ns.n_dirs() as i64 - 1) as u32);
            let files = ns.dir(dir).files;
            let inode = if files > 0 && g.bool() {
                InodeRef::file(dir, g.int(0, files as i64 - 1) as u32)
            } else {
                InodeRef::dir(dir)
            };
            let path = file_path(&ns, inode);
            match g.int(0, 3) {
                0 => {
                    let v = g.int(0, 1000) as u64;
                    trie.insert(&path, v);
                    interned.insert_version(inode, v);
                }
                1 => {
                    let t = trie.peek(&path).copied();
                    let i = interned.peek_version(inode);
                    ensure_eq(t, i, &format!("lookup {path}"))?;
                }
                2 => {
                    let t = trie.invalidate(&path);
                    let i = interned.invalidate(inode);
                    ensure_eq(t, i, &format!("invalidate {path}"))?;
                }
                _ => {
                    // Subtree invalidation rooted at a random directory.
                    let root = DirId(g.int(0, ns.n_dirs() as i64 - 1) as u32);
                    let t = trie.invalidate_prefix(&ns.dir(root).path);
                    let i = interned.invalidate_subtree(&ns, root);
                    ensure_eq(t, i, &format!("subtree inv at {}", ns.dir(root).path))?;
                }
            }
            ensure_eq(trie.len(), interned.len(), "cache sizes")?;
        }
        Ok(())
    });
}

#[test]
fn subtree_invalidation_never_leaks_outside_subtree() {
    let mut seed_rng = lambda_fs::util::rng::Rng::new(5);
    let ns = generate(
        &NamespaceParams { n_dirs: 128, files_per_dir: 3, max_depth: 5, zipf_s: 1.2 },
        &mut seed_rng,
    );
    ptest::check("subtree inv isolation", 200, |g| {
        let mut cache = InternedCache::new(100_000);
        // Fill with a random population.
        let mut population = Vec::new();
        for _ in 0..g.int(5, 80) {
            let dir = DirId(g.int(0, ns.n_dirs() as i64 - 1) as u32);
            let inode = InodeRef::dir(dir);
            cache.insert_version(inode, 1);
            population.push(inode);
        }
        let root = DirId(g.int(0, ns.n_dirs() as i64 - 1) as u32);
        let subtree: std::collections::HashSet<DirId> =
            ns.subtree_dirs(root).into_iter().collect();
        cache.invalidate_subtree(&ns, root);
        for inode in population {
            let inside = subtree.contains(&inode.dir);
            let present = cache.peek(inode);
            if inside {
                ensure(!present, "inside subtree must be invalidated")?;
            }
            // Outside entries must survive *iff* they were not separately
            // invalidated — they were not, so:
            if !inside {
                ensure(present, "outside subtree must survive")?;
            }
        }
        Ok(())
    });
}
