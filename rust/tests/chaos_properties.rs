//! Randomized chaos-plan property sweep (PR 10): seeded random
//! [`ChaosPlan`]s — kills, blackouts, partitions, delay windows,
//! straggler bursts, and invalidation-ack chaos in arbitrary
//! combinations — each driven through λFS, HopsFS+Cache, and CephFS.
//!
//! Whatever the plan throws, the bookkeeping invariants must hold:
//!
//! * **Op conservation** — `completed + gave_up == submitted`: no op is
//!   lost or double-counted, however it died.
//! * **Placement conservation** — `cold_starts + warm_ops == completed`
//!   and the tier ledger `pool_hits + restores + ephemeral_boots ==
//!   cold_starts`.
//! * **Intent conservation** — `orphaned_ops == recovered_ops +
//!   aborted_ops`: every intent opened by an instance that died mid-op
//!   is either replayed (durable intent, late ack) or aborted and
//!   retried — never silently dropped. Serverful baselines have no
//!   instances to orphan ops on, so their recovery counters stay zero.
//! * **Consistency** — the always-on auditor (`audit::Auditor`) reports
//!   zero violations: no lost acked write, read-your-writes per client,
//!   no stale read after an acked invalidation, and no leaked locks at
//!   drain. A nonzero count under *any* plan is a correctness bug in
//!   recovery, not a fault-injection artifact.
//! * **Determinism** — the same seed and plan reproduce the run bit for
//!   bit (`fingerprint` and `outcome_fingerprint`), chaos included.
//!
//! Plan 0 is not random: it is the kill-storm shape (a kill in every
//! deployment at every second plus ack chaos), pinning that the sweep
//! actually exercises the orphan/recovery path rather than sampling
//! only quiet corners of the plan space.

use lambda_fs::baselines::hopsfs::HopsFs;
use lambda_fs::baselines::CephFs;
use lambda_fs::chaos::{
    AckChaos, Blackout, ChaosPlan, DelayWindow, KillEvent, Partition, StragglerBurst,
};
use lambda_fs::config::SystemConfig;
use lambda_fs::metrics::RunMetrics;
use lambda_fs::namespace::generate::{generate, HotspotSampler, NamespaceParams};
use lambda_fs::namespace::Namespace;
use lambda_fs::systems::{driver, LambdaFs, MetadataService};
use lambda_fs::util::rng::Rng;
use lambda_fs::workload::{OpMix, OpenLoopSpec, ThroughputSchedule};

const DURATION_S: usize = 8;
const RATE: f64 = 700.0;
const N_CLIENTS: u32 = 64;
const N_VMS: u32 = 2;
const N_DEPLOYMENTS: u32 = 8;
const N_PLANS: u64 = 6;

/// The kill-storm shape (plan 0): a kill in every one of the first four
/// deployments at every second boundary, under invalidation-ack chaos
/// that stretches serve windows across those boundaries.
fn storm_plan() -> ChaosPlan {
    let end = DURATION_S as u32;
    ChaosPlan {
        n_vms: N_VMS,
        kills: (1..end)
            .flat_map(|s| (0..4).map(move |d| KillEvent { second: s, deployment: d }))
            .collect(),
        acks: vec![AckChaos { from_s: 0, to_s: end, drop_prob: 0.35, delay_ms: 250.0 }],
        ..ChaosPlan::none()
    }
}

/// Draw a random plan: each fault category appears with some
/// probability, with random (bounded) windows and magnitudes.
fn random_plan(rng: &mut Rng) -> ChaosPlan {
    let end = DURATION_S as u32;
    let mut plan = ChaosPlan::none();
    plan.n_vms = N_VMS;
    for _ in 0..rng.below(6) {
        plan.kills.push(KillEvent {
            second: 1 + rng.below(u64::from(end) - 1) as u32,
            deployment: rng.below(u64::from(N_DEPLOYMENTS)) as u32,
        });
    }
    if rng.chance(0.5) {
        let from = rng.below(u64::from(end) - 2) as u32;
        let dep = if rng.chance(0.7) {
            Some(rng.below(u64::from(N_DEPLOYMENTS)) as u32)
        } else {
            None // coordinator blackout: writes stall
        };
        plan.blackouts.push(Blackout {
            from_s: from,
            to_s: from + 1 + rng.below(3) as u32,
            deployment: dep,
        });
    }
    if rng.chance(0.5) {
        let from = rng.below(u64::from(end) - 1) as u32;
        // Half the partitions heal, half hold to the end of the run.
        let to = if rng.chance(0.5) { from + 1 + rng.below(3) as u32 } else { u32::MAX };
        plan.partitions.push(Partition {
            from_s: from,
            to_s: to,
            vm: rng.below(u64::from(N_VMS)) as u32,
            deployment: rng.below(u64::from(N_DEPLOYMENTS)) as u32,
        });
    }
    if rng.chance(0.5) {
        plan.delays.push(DelayWindow {
            from_s: 0,
            to_s: end,
            tcp_mult: 2.0 + rng.f64() * 10.0,
            http_mult: 2.0 + rng.f64() * 10.0,
        });
    }
    if rng.chance(0.5) {
        plan.stragglers.push(StragglerBurst {
            from_s: 0,
            to_s: end,
            prob: 0.05 + rng.f64() * 0.15,
            factor: 10.0 + rng.f64() * 30.0,
        });
    }
    if rng.chance(0.5) {
        plan.acks.push(AckChaos {
            from_s: 0,
            to_s: end,
            drop_prob: rng.f64() * 0.4,
            delay_ms: rng.f64() * 300.0,
        });
    }
    plan
}

fn fixture(seed: u64) -> (SystemConfig, Namespace, HotspotSampler) {
    let mut cfg = SystemConfig::default();
    cfg.seed = seed;
    cfg.lambda_fs.n_deployments = N_DEPLOYMENTS;
    let mut rng = Rng::new(cfg.seed);
    let ns = generate(
        &NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() },
        &mut rng,
    );
    let sampler = HotspotSampler::new(&ns, 1.3, &mut rng);
    (cfg, ns, sampler)
}

fn spec() -> OpenLoopSpec {
    OpenLoopSpec {
        schedule: ThroughputSchedule::constant(DURATION_S, RATE),
        mix: OpMix::spotify(),
        n_clients: N_CLIENTS,
        n_vms: N_VMS,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    }
}

fn run_system<S, F>(mk: F, plan: &ChaosPlan, seed: u64) -> RunMetrics
where
    S: MetadataService,
    F: Fn() -> S,
{
    let (_cfg, ns, sampler) = fixture(seed);
    let mut sys = mk();
    sys.install_chaos(plan);
    let mut rng = Rng::new(seed ^ 0xc4a05);
    driver::run_open_loop(&mut sys, &spec(), &ns, &sampler, &mut rng);
    sys.into_metrics()
}

/// Assert every conservation law on one system's run under one plan.
fn check_invariants(m: &RunMetrics, what: &str) {
    let submitted = DURATION_S as u64 * RATE as u64;
    assert_eq!(m.completed_ops + m.gave_up, submitted, "{what}: op conservation");
    assert_eq!(m.failed_ops, m.gave_up, "{what}: give-ups are the only failures");
    assert_eq!(m.cold_starts + m.warm_ops, m.completed_ops, "{what}: placement conservation");
    assert_eq!(
        m.pool_hits + m.restores + m.ephemeral_boots,
        m.cold_starts,
        "{what}: tier conservation"
    );
    assert_eq!(m.orphaned_ops, m.recovered_ops + m.aborted_ops, "{what}: intent conservation");
    assert_eq!(m.audit_violations, 0, "{what}: consistency auditor found violations");
}

#[test]
fn random_plans_conserve_and_audit_clean_all_systems() {
    for plan_idx in 0..N_PLANS {
        let mut plan_rng = Rng::new(0x91a75 ^ plan_idx);
        let plan = if plan_idx == 0 { storm_plan() } else { random_plan(&mut plan_rng) };
        let seed = 0x77aa ^ (plan_idx * 0x9e3779b9);

        let (cfg, ns, _) = fixture(seed);

        // λFS: the full recovery machinery is in play.
        let mk_lfs = || LambdaFs::new(cfg.clone(), ns.clone(), N_CLIENTS, N_VMS);
        let a = run_system(mk_lfs, &plan, seed);
        let b = run_system(mk_lfs, &plan, seed);
        assert_eq!(a.fingerprint(), b.fingerprint(), "plan {plan_idx}: λFS diverged");
        assert_eq!(
            a.outcome_fingerprint(),
            b.outcome_fingerprint(),
            "plan {plan_idx}: λFS ledger diverged"
        );
        check_invariants(&a, &format!("plan {plan_idx} λFS"));
        if plan_idx == 0 {
            // The storm pin: the sweep reaches the orphan/recovery path.
            assert!(a.orphaned_ops > 0, "storm plan orphaned nothing");
            assert!(a.locks_reclaimed > 0, "storm plan reclaimed no locks");
        }
        if plan.kills.is_empty() {
            assert_eq!(a.orphaned_ops, 0, "plan {plan_idx}: orphans without kills");
            assert_eq!(a.locks_reclaimed, 0, "plan {plan_idx}: reclaims without kills");
        }

        // HopsFS+Cache and CephFS: serverful — same laws, zero orphans.
        let mk_hops = || HopsFs::new(cfg.clone(), ns.clone(), 128.0, true);
        let h = run_system(mk_hops, &plan, seed);
        let h2 = run_system(mk_hops, &plan, seed);
        assert_eq!(
            h.outcome_fingerprint(),
            h2.outcome_fingerprint(),
            "plan {plan_idx}: HopsFS diverged"
        );
        check_invariants(&h, &format!("plan {plan_idx} HopsFS+Cache"));
        assert_eq!(h.orphaned_ops, 0, "plan {plan_idx}: HopsFS has no instances to orphan");

        let mk_ceph = || CephFs::new(cfg.clone(), ns.clone(), 128.0);
        let ce = run_system(mk_ceph, &plan, seed);
        let ce2 = run_system(mk_ceph, &plan, seed);
        assert_eq!(
            ce.outcome_fingerprint(),
            ce2.outcome_fingerprint(),
            "plan {plan_idx}: CephFS diverged"
        );
        check_invariants(&ce, &format!("plan {plan_idx} CephFS"));
        assert_eq!(ce.orphaned_ops, 0, "plan {plan_idx}: CephFS has no instances to orphan");
    }
}
