//! Property tests on coordinator invariants: routing stability, batching
//! conservation, coherence freshness, and store serializability.

use lambda_fs::client::Router;
use lambda_fs::config::SystemConfig;
use lambda_fs::coordinator::subtree::SubtreePlan;
use lambda_fs::namespace::generate::{generate, NamespaceParams};
use lambda_fs::namespace::{DirId, InodeRef, Namespace};
use lambda_fs::store::NdbStore;
use lambda_fs::util::fnv;
use lambda_fs::util::ptest::{self, ensure, ensure_eq};
use lambda_fs::util::rng::Rng;

fn ns_fixture(seed: u64, dirs: usize) -> Namespace {
    let mut rng = Rng::new(seed);
    generate(&NamespaceParams { n_dirs: dirs, files_per_dir: 8, ..Default::default() }, &mut rng)
}

#[test]
fn routing_is_deterministic_and_partition_stable() {
    let ns = ns_fixture(11, 256);
    ptest::check("routing determinism", 300, |g| {
        let n_dep = g.int(1, 64) as u32;
        let router = Router::build(&ns, n_dep);
        let dir = DirId(g.int(0, ns.n_dirs() as i64 - 1) as u32);
        let files = ns.dir(dir).files;
        let inode = if files > 0 && g.bool() {
            InodeRef::file(dir, g.int(0, files as i64 - 1) as u32)
        } else {
            InodeRef::dir(dir)
        };
        let d1 = router.route(&ns, inode);
        let d2 = router.route(&ns, inode);
        ensure_eq(d1, d2, "same inode, same deployment")?;
        ensure(d1 < n_dep, "deployment in range")?;
        // Partition stability: routing matches the raw FNV contract.
        let expect = fnv::route(ns.parent_path(inode), n_dep);
        ensure_eq(d1, expect, "matches kernel contract")?;
        // Co-location: all files of a directory share a deployment
        // (a directory itself routes by its parent, so compare files).
        if files > 1 {
            let f1 = InodeRef::file(dir, g.int(0, files as i64 - 1) as u32);
            let f2 = InodeRef::file(dir, g.int(0, files as i64 - 1) as u32);
            ensure_eq(router.route(&ns, f1), router.route(&ns, f2), "files co-locate")?;
        }
        Ok(())
    });
}

#[test]
fn subtree_batching_conserves_inodes() {
    let ns = ns_fixture(13, 512);
    ptest::check("batch conservation", 200, |g| {
        let root = DirId(g.int(0, ns.n_dirs() as i64 - 1) as u32);
        let plan = SubtreePlan::build(&ns, root, |d| fnv::route(&ns.dir(d).path, 16));
        let batch = g.int(1, 2048) as usize;
        let n_batches = plan.n_batches(batch);
        // Conservation: batches cover exactly the subtree's INodes.
        let batch_u64 = batch as u64;
        ensure(n_batches * batch_u64 >= plan.total_inodes, "batches cover all inodes")?;
        ensure(
            (n_batches - 1) * batch_u64 < plan.total_inodes,
            "no fully-empty trailing batch",
        )?;
        // The plan's dirs match the namespace's subtree enumeration.
        let expect: std::collections::HashSet<DirId> =
            ns.subtree_dirs(root).into_iter().collect();
        let got: std::collections::HashSet<DirId> = plan.dirs.iter().copied().collect();
        ensure_eq(got.len(), plan.dirs.len(), "no duplicate dirs in plan")?;
        ensure(got == expect, "plan dirs == subtree dirs")?;
        // Deployment set is exactly the routes of the subtree's dirs.
        for d in &plan.dirs {
            let dep = fnv::route(&ns.dir(*d).path, 16);
            ensure(plan.deployments.contains(&dep), "deployment set covers dir")?;
        }
        Ok(())
    });
}

#[test]
fn store_writes_serialize_per_row() {
    ptest::check("store serializability", 150, |g| {
        let mut store = NdbStore::new(SystemConfig::default().store);
        let mut rng = Rng::new(g.int(0, i64::MAX) as u64);
        let row = InodeRef::file(DirId(1), 0);
        let mut commits = Vec::new();
        let n = g.int(2, 20);
        for _ in 0..n {
            commits.push(store.write_txn(0, &[row], false, &mut rng));
        }
        // Commits on one row are strictly ordered (exclusive locks).
        for w in commits.windows(2) {
            ensure(w[0] < w[1], "row commits strictly ordered")?;
        }
        ensure_eq(store.version(row), n as u64, "version counts commits")?;
        Ok(())
    });
}

#[test]
fn concurrent_disjoint_writes_do_not_serialize() {
    ptest::check("disjoint concurrency", 100, |g| {
        let mut store = NdbStore::new(SystemConfig::default().store);
        let mut rng = Rng::new(g.int(0, i64::MAX) as u64);
        let n = g.int(2, 30) as u32;
        let commits: Vec<_> = (0..n)
            .map(|i| store.write_txn(0, &[InodeRef::file(DirId(i), 0)], false, &mut rng))
            .collect();
        // With 128 store slots, disjoint writes all land within ~one
        // service time — far sooner than n serialized writes would.
        let serial_bound = lambda_fs::sim::time::from_ms(1.55 * 0.8) * n as u64;
        let max = commits.iter().max().unwrap();
        ensure(*max < serial_bound.max(5_000), "disjoint writes run concurrently")?;
        Ok(())
    });
}

#[test]
fn write_deployments_always_cover_read_route() {
    // Coherence prerequisite: the set of deployments invalidated by a
    // write must include the deployment any reader would consult.
    let ns = ns_fixture(17, 256);
    ptest::check("invalidation covers readers", 300, |g| {
        let n_dep = g.int(1, 32) as u32;
        let router = Router::build(&ns, n_dep);
        let dir = DirId(g.int(0, ns.n_dirs() as i64 - 1) as u32);
        let files = ns.dir(dir).files;
        let inode = if files > 0 && g.bool() {
            InodeRef::file(dir, g.int(0, files as i64 - 1) as u32)
        } else {
            InodeRef::dir(dir)
        };
        let deps = router.write_deployments(&ns, inode);
        ensure(deps.contains(&router.route(&ns, inode)), "reader's deployment covered")?;
        Ok(())
    });
}
