//! Determinism regression tests (the PR-1 perf overhaul contract).
//!
//! The calendar-queue scheduler, the FNV hot-path maps, and the
//! allocation-free submit path must not change a single simulated
//! outcome — only wall-clock speed. Two guarantees are pinned here:
//!
//! 1. **Same seed → same run.** Running any system twice with one seed
//!    produces bit-identical `RunMetrics` (fingerprint over counters,
//!    the full per-second series, and all latency histograms).
//! 2. **Calendar queue ≡ reference heap.** The wheel scheduler pops the
//!    exact `(time, seq)` sequence the reference `BinaryHeap` pops, over
//!    randomized schedules that interleave scheduling with popping and
//!    cross the overflow horizon both ways.

use lambda_fs::baselines::hopsfs::HopsFs;
use lambda_fs::config::SystemConfig;
use lambda_fs::metrics::RunMetrics;
use lambda_fs::namespace::generate::{generate, HotspotSampler, NamespaceParams};
use lambda_fs::namespace::Namespace;
use lambda_fs::sim::queue::{EventQueue, HeapQueue};
use lambda_fs::systems::{driver, LambdaFs, MdsSim};
use lambda_fs::trace::{replay_into, Recorder, Trace, TraceMeta};
use lambda_fs::util::rng::Rng;
use lambda_fs::workload::{ClosedLoopSpec, OpMix, OpenLoopSpec, ThroughputSchedule};

fn fixture(seed: u64) -> (SystemConfig, Namespace, HotspotSampler) {
    let mut cfg = SystemConfig::default();
    cfg.seed = seed;
    cfg.lambda_fs.n_deployments = 8;
    let mut rng = Rng::new(cfg.seed);
    let ns = generate(
        &NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() },
        &mut rng,
    );
    let sampler = HotspotSampler::new(&ns, 1.3, &mut rng);
    (cfg, ns, sampler)
}

fn run_lambdafs_open(seed: u64) -> RunMetrics {
    let (cfg, ns, sampler) = fixture(seed);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(8, 800.0),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    };
    let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    let mut rng = Rng::new(cfg.seed ^ 0xd0);
    driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
    sys.into_metrics()
}

#[test]
fn same_seed_identical_run_metrics_open_loop() {
    let a = run_lambdafs_open(1234);
    let b = run_lambdafs_open(1234);
    assert_eq!(a.completed_ops, b.completed_ops);
    assert_eq!(a.fingerprint(), b.fingerprint(), "open-loop runs diverged");
    // And a different seed actually moves the fingerprint (the digest is
    // not degenerate).
    let c = run_lambdafs_open(4321);
    assert_ne!(a.fingerprint(), c.fingerprint(), "fingerprint insensitive to seed");
}

#[test]
fn same_seed_identical_run_metrics_closed_loop() {
    let run = |seed: u64| -> RunMetrics {
        let (cfg, ns, sampler) = fixture(seed);
        let spec = ClosedLoopSpec {
            kind: lambda_fs::namespace::OpKind::Read,
            n_clients: 32,
            n_vms: 2,
            ops_per_client: 150,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        let mut rng = Rng::new(cfg.seed ^ 0xc1);
        driver::run_closed_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    // The closed-loop driver runs on the calendar queue itself, so this
    // also pins the scheduler's end-to-end determinism.
    assert_eq!(run(77).fingerprint(), run(77).fingerprint());
}

#[test]
fn same_seed_identical_run_metrics_hopsfs() {
    let run = |seed: u64| -> RunMetrics {
        let (cfg, ns, sampler) = fixture(seed);
        let spec = OpenLoopSpec {
            schedule: ThroughputSchedule::constant(5, 500.0),
            mix: OpMix::spotify(),
            n_clients: 64,
            n_vms: 2,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = HopsFs::new(cfg.clone(), ns.clone(), 128.0, true);
        let mut rng = Rng::new(cfg.seed ^ 0xb0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    assert_eq!(run(9).fingerprint(), run(9).fingerprint(), "HopsFS runs diverged");
}

/// The calendar queue and the reference heap pop identical
/// `(time, seq, event)` sequences over randomized interleaved schedules.
#[test]
fn calendar_queue_differential_randomized() {
    for trial in 0..30u64 {
        let mut decide = Rng::new(0xd1ff ^ trial);
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut heap: HeapQueue<u64> = HeapQueue::new();
        let mut ev = 0u64;
        for _ in 0..5_000 {
            if decide.chance(0.55) {
                // Delay profile mixes ties, in-wheel, and overflow-tier
                // distances (wheel horizon is 4096 * 64 µs ≈ 0.26 s).
                let delay = match decide.below(4) {
                    0 => 0,
                    1 => decide.below(128),
                    2 => decide.below(200_000),
                    _ => 200_000 + decide.below(2_000_000),
                };
                cal.schedule_in(delay, ev);
                heap.schedule_in(delay, ev);
                ev += 1;
            } else {
                let (x, y) = (cal.pop(), heap.pop());
                match (x, y) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!(
                            (x.at, x.seq, x.event),
                            (y.at, y.seq, y.event),
                            "trial {trial} diverged"
                        );
                        assert_eq!(cal.now(), heap.now());
                    }
                    (x, y) => panic!("trial {trial}: {x:?} vs {y:?}"),
                }
            }
            assert_eq!(cal.len(), heap.len());
        }
        loop {
            match (cal.pop(), heap.pop()) {
                (None, None) => break,
                (Some(x), Some(y)) => {
                    assert_eq!((x.at, x.seq, x.event), (y.at, y.seq, y.event))
                }
                (x, y) => panic!("trial {trial} tail: {x:?} vs {y:?}"),
            }
        }
        assert_eq!(cal.processed(), heap.processed());
    }
}

/// The trace engine's record→replay contract: capturing a seeded λFS
/// Spotify run through `Recorder`, round-tripping the trace through the
/// binary format, and replaying it into a fresh same-seed λFS produces a
/// bit-identical `RunMetrics::fingerprint`. Cross-system replays of the
/// same trace complete the identical op stream.
#[test]
fn trace_record_replay_bit_identical_spotify() {
    let seed = 2024u64;
    let (cfg, ns, sampler) = fixture(seed);
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };
    let mut sched_rng = Rng::new(seed ^ 0x5c);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::pareto_bursty(6, 3, 600.0, 2.0, 7.0, &mut sched_rng),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("spotify", seed, &params, spec.n_clients, spec.n_vms);

    // Record.
    let mut rec =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), meta);
    let mut rng = Rng::new(cfg.seed ^ 0xabcd);
    driver::run_open_loop(&mut rec, &spec, &ns, &sampler, &mut rng);
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();
    assert_eq!(trace.n_ops(), m_rec.completed_ops, "every submit captured");

    // Binary format round trip.
    let bytes = trace.encode();
    let decoded = Trace::decode(&bytes).expect("decode recorded trace");
    assert_eq!(trace, decoded);
    assert_eq!(trace.fingerprint(), decoded.fingerprint());

    // Bit-identical replay into a fresh same-seed λFS.
    let m_rep = replay_into(
        LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms),
        &decoded,
        &mut Rng::new(cfg.seed ^ 0xabcd),
    );
    assert_eq!(
        m_rec.fingerprint(),
        m_rep.fingerprint(),
        "record→replay must reproduce the run bit for bit"
    );

    // Cross-system: the identical op stream drives a baseline to
    // completion.
    let m_hops = replay_into(
        HopsFs::new(cfg.clone(), ns.clone(), 128.0, true),
        &decoded,
        &mut Rng::new(cfg.seed ^ 0x40b5),
    );
    assert_eq!(m_hops.completed_ops, decoded.n_ops());
}

/// Closed-loop runs (driven off the calendar queue) round-trip too.
#[test]
fn trace_record_replay_bit_identical_closed_loop() {
    let seed = 99u64;
    let (cfg, ns, sampler) = fixture(seed);
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };
    let spec = ClosedLoopSpec {
        kind: lambda_fs::namespace::OpKind::Read,
        n_clients: 24,
        n_vms: 2,
        ops_per_client: 120,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("micro-read", seed, &params, spec.n_clients, spec.n_vms);
    let mut rec =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), meta);
    let mut rng = Rng::new(cfg.seed ^ 0xc10);
    driver::run_closed_loop(&mut rec, &spec, &ns, &sampler, &mut rng);
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();

    let m_rep = replay_into(
        LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms),
        &trace,
        &mut Rng::new(cfg.seed ^ 0xc10),
    );
    assert_eq!(m_rec.fingerprint(), m_rep.fingerprint(), "closed-loop round trip diverged");
}

/// Driving the *same closed-loop workload* through both queue
/// implementations yields the same submission order end to end.
#[test]
fn closed_loop_schedule_differential() {
    // Simulate the closed-loop driver's queue usage pattern: clients
    // reschedule themselves at their (deterministic) completion times.
    let service = |c: u64, t: u64| 500 + ((c * 2654435761 + t) % 3_000);
    let run_with = |use_cal: bool| -> Vec<(u64, u64)> {
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut heap: HeapQueue<u64> = HeapQueue::new();
        for c in 0..64u64 {
            if use_cal {
                cal.schedule_at(c * 100, c);
            } else {
                heap.schedule_at(c * 100, c);
            }
        }
        let mut order = Vec::new();
        let mut remaining = vec![50u32; 64];
        loop {
            let s = if use_cal { cal.pop() } else { heap.pop() };
            let Some(s) = s else { break };
            order.push((s.at, s.event));
            let c = s.event as usize;
            remaining[c] -= 1;
            if remaining[c] > 0 {
                let done = s.at + service(s.event, s.at);
                if use_cal {
                    cal.schedule_at(done, s.event);
                } else {
                    heap.schedule_at(done, s.event);
                }
            }
        }
        order
    };
    assert_eq!(run_with(true), run_with(false));
}
