//! Determinism regression tests (the PR-1 perf overhaul contract,
//! extended by the outcome-bearing `MetadataService` migration).
//!
//! The calendar-queue scheduler, the FNV hot-path maps, the
//! allocation-free submit path, and the typed-request API must not
//! change a single simulated outcome — only wall-clock speed. Pinned
//! here:
//!
//! 1. **Same seed → same run.** Running any system twice with one seed
//!    produces bit-identical `RunMetrics` (fingerprint over counters,
//!    the full per-second series, all latency histograms, and the
//!    per-op outcome ledger).
//! 2. **Calendar queue ≡ reference heap.** The wheel scheduler pops the
//!    exact `(time, seq)` sequence the reference `BinaryHeap` pops, over
//!    randomized schedules that interleave scheduling with popping and
//!    cross the overflow horizon both ways.
//! 3. **`submit_batch` ≡ `submit`.** The batched open-loop driver (λFS'
//!    amortized-routing override and the default scalar-loop impl the
//!    baselines inherit) reproduces the scalar driver's fingerprint bit
//!    for bit, and outcome counters are conserved
//!    (`cold_starts + warm_ops == completed_ops`).
//! 4. **Saturation-proof recording.** Traces record *intended* slots,
//!    so a recording made under saturation replays the pure schedule.
//! 5. **Table-driven sampling substrate (PR 5).** Every distribution
//!    sample consumes exactly one RNG draw (quantile LUT / alias table —
//!    `util::dist`), and the integer-bucketed histogram keeps its
//!    conservation invariants at the system level. The substrate switch
//!    intentionally shifted sampled values, so fingerprints recorded
//!    before PR 5 are not comparable to post-PR-5 runs (ROADMAP
//!    artifact-comparability note); every test here pins *relative*
//!    equalities, which re-pin the new values automatically.
//! 6. **Sharded engine (PR 8, `sim::shard`).** Conservative-window
//!    parallel runs are deterministic in the seed, invariant in the
//!    worker-thread count (`Sequential` ≡ `ThreadPool` per shard AND
//!    merged), record→replay bit-identically, and a single-shard plan
//!    reproduces the classic sequential driver exactly. Sharded runs are
//!    their own fingerprint domain — none of these pins compare a
//!    multi-shard run against an unsharded one.
//! 7. **Cold-start tier ladder (PR 9, `faas::platform::TierLadder`).**
//!    The default config keeps the ladder OFF: every cold start stays on
//!    the ephemeral rung, the pool/restore counters stay zero, and the
//!    outcome digest keeps its pre-ladder hash domain (the tier counters
//!    fold only when an upper rung fired). Ladder-on runs draw all tier
//!    latencies from a dedicated `fork("tier-ladder")` stream, so the
//!    caller's RNG sequence is byte-identical either way; predictive
//!    prewarming is RNG-free and composes with record→replay.
//! 8. **Crash recovery (PR 10, `coherence::recovery` + the NDB intent
//!    log).** Recovery draws (retry backoffs) ride a dedicated
//!    `fork("recovery")` stream, so kill-free runs are byte-identical
//!    whatever `store.recovery_lease_ms` or `faas.checkpoint_ttl_s` say
//!    — the machinery is invisible until an instance actually dies.
//!    Kill-storm replays (the dir-reorg workload under per-second kills
//!    + ack chaos) are deterministic in the seed, conserve the intent
//!    ledger (`orphaned == recovered + aborted`), and keep the always-on
//!    consistency auditor silent. See `tests/chaos_properties.rs` for
//!    the randomized-plan property sweep.
//!
//! The fingerprint-domain history across PRs (which digests are
//! comparable to which) is consolidated in `docs/DETERMINISM.md`.

use lambda_fs::baselines::hopsfs::HopsFs;
use lambda_fs::baselines::{CephFs, InfiniCacheMds};
use lambda_fs::chaos::{
    AckChaos, Blackout, ChaosPlan, DelayWindow, KillEvent, Partition, StragglerBurst,
};
use lambda_fs::config::SystemConfig;
use lambda_fs::faas::{Platform, ReferencePlatform};
use lambda_fs::metrics::RunMetrics;
use lambda_fs::namespace::generate::{generate, HotspotSampler, NamespaceParams};
use lambda_fs::namespace::Namespace;
use lambda_fs::sim::queue::{EventQueue, HeapQueue};
use lambda_fs::sim::shard::{
    replay_sharded, run_open_loop_sharded, Executor, Sequential, ShardPlan, ThreadPool,
};
use lambda_fs::sim::time;
use lambda_fs::systems::{driver, LambdaFs, MetadataService};
use lambda_fs::trace::synth::{self, ContainerChurnSpec, DirReorgSpec};
use lambda_fs::trace::{replay, replay_into, Recorder, Trace, TraceEvent, TraceMeta};
use lambda_fs::util::rng::Rng;
use lambda_fs::workload::{ClosedLoopSpec, OpMix, OpenLoopSpec, ThroughputSchedule};

fn fixture(seed: u64) -> (SystemConfig, Namespace, HotspotSampler) {
    let mut cfg = SystemConfig::default();
    cfg.seed = seed;
    cfg.lambda_fs.n_deployments = 8;
    let mut rng = Rng::new(cfg.seed);
    let ns = generate(
        &NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() },
        &mut rng,
    );
    let sampler = HotspotSampler::new(&ns, 1.3, &mut rng);
    (cfg, ns, sampler)
}

fn run_lambdafs_open(seed: u64) -> RunMetrics {
    let (cfg, ns, sampler) = fixture(seed);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(8, 800.0),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    };
    let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    let mut rng = Rng::new(cfg.seed ^ 0xd0);
    driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
    sys.into_metrics()
}

#[test]
fn same_seed_identical_run_metrics_open_loop() {
    let a = run_lambdafs_open(1234);
    let b = run_lambdafs_open(1234);
    assert_eq!(a.completed_ops, b.completed_ops);
    assert_eq!(a.fingerprint(), b.fingerprint(), "open-loop runs diverged");
    // And a different seed actually moves the fingerprint (the digest is
    // not degenerate).
    let c = run_lambdafs_open(4321);
    assert_ne!(a.fingerprint(), c.fingerprint(), "fingerprint insensitive to seed");
}

#[test]
fn same_seed_identical_run_metrics_closed_loop() {
    let run = |seed: u64| -> RunMetrics {
        let (cfg, ns, sampler) = fixture(seed);
        let spec = ClosedLoopSpec {
            kind: lambda_fs::namespace::OpKind::Read,
            n_clients: 32,
            n_vms: 2,
            ops_per_client: 150,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        let mut rng = Rng::new(cfg.seed ^ 0xc1);
        driver::run_closed_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    // The closed-loop driver runs on the calendar queue itself, so this
    // also pins the scheduler's end-to-end determinism.
    assert_eq!(run(77).fingerprint(), run(77).fingerprint());
}

#[test]
fn same_seed_identical_run_metrics_hopsfs() {
    let run = |seed: u64| -> RunMetrics {
        let (cfg, ns, sampler) = fixture(seed);
        let spec = OpenLoopSpec {
            schedule: ThroughputSchedule::constant(5, 500.0),
            mix: OpMix::spotify(),
            n_clients: 64,
            n_vms: 2,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = HopsFs::new(cfg.clone(), ns.clone(), 128.0, true);
        let mut rng = Rng::new(cfg.seed ^ 0xb0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    assert_eq!(run(9).fingerprint(), run(9).fingerprint(), "HopsFS runs diverged");
}

/// The calendar queue and the reference heap pop identical
/// `(time, seq, event)` sequences over randomized interleaved schedules.
#[test]
fn calendar_queue_differential_randomized() {
    for trial in 0..30u64 {
        let mut decide = Rng::new(0xd1ff ^ trial);
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut heap: HeapQueue<u64> = HeapQueue::new();
        let mut ev = 0u64;
        for _ in 0..5_000 {
            if decide.chance(0.55) {
                // Delay profile mixes ties, in-wheel, and overflow-tier
                // distances (wheel horizon is 4096 * 64 µs ≈ 0.26 s).
                let delay = match decide.below(4) {
                    0 => 0,
                    1 => decide.below(128),
                    2 => decide.below(200_000),
                    _ => 200_000 + decide.below(2_000_000),
                };
                cal.schedule_in(delay, ev);
                heap.schedule_in(delay, ev);
                ev += 1;
            } else {
                let (x, y) = (cal.pop(), heap.pop());
                match (x, y) {
                    (None, None) => {}
                    (Some(x), Some(y)) => {
                        assert_eq!(
                            (x.at, x.seq, x.event),
                            (y.at, y.seq, y.event),
                            "trial {trial} diverged"
                        );
                        assert_eq!(cal.now(), heap.now());
                    }
                    (x, y) => panic!("trial {trial}: {x:?} vs {y:?}"),
                }
            }
            assert_eq!(cal.len(), heap.len());
        }
        loop {
            match (cal.pop(), heap.pop()) {
                (None, None) => break,
                (Some(x), Some(y)) => {
                    assert_eq!((x.at, x.seq, x.event), (y.at, y.seq, y.event))
                }
                (x, y) => panic!("trial {trial} tail: {x:?} vs {y:?}"),
            }
        }
        assert_eq!(cal.processed(), heap.processed());
    }
}

/// The trace engine's record→replay contract: capturing a seeded λFS
/// Spotify run through `Recorder`, round-tripping the trace through the
/// binary format, and replaying it into a fresh same-seed λFS produces a
/// bit-identical `RunMetrics::fingerprint`. Cross-system replays of the
/// same trace complete the identical op stream.
#[test]
fn trace_record_replay_bit_identical_spotify() {
    let seed = 2024u64;
    let (cfg, ns, sampler) = fixture(seed);
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };
    let mut sched_rng = Rng::new(seed ^ 0x5c);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::pareto_bursty(6, 3, 600.0, 2.0, 7.0, &mut sched_rng),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("spotify", seed, &params, spec.n_clients, spec.n_vms);

    // Record.
    let mut rec =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), meta);
    let mut rng = Rng::new(cfg.seed ^ 0xabcd);
    driver::run_open_loop(&mut rec, &spec, &ns, &sampler, &mut rng);
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();
    assert_eq!(trace.n_ops(), m_rec.completed_ops, "every submit captured");

    // Binary format round trip.
    let bytes = trace.encode();
    let decoded = Trace::decode(&bytes).expect("decode recorded trace");
    assert_eq!(trace, decoded);
    assert_eq!(trace.fingerprint(), decoded.fingerprint());

    // Bit-identical replay into a fresh same-seed λFS.
    let m_rep = replay_into(
        LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms),
        &decoded,
        &mut Rng::new(cfg.seed ^ 0xabcd),
    );
    assert_eq!(
        m_rec.fingerprint(),
        m_rep.fingerprint(),
        "record→replay must reproduce the run bit for bit"
    );

    // Cross-system: the identical op stream drives a baseline to
    // completion.
    let m_hops = replay_into(
        HopsFs::new(cfg.clone(), ns.clone(), 128.0, true),
        &decoded,
        &mut Rng::new(cfg.seed ^ 0x40b5),
    );
    assert_eq!(m_hops.completed_ops, decoded.n_ops());
}

/// Closed-loop runs (driven off the calendar queue) round-trip too.
#[test]
fn trace_record_replay_bit_identical_closed_loop() {
    let seed = 99u64;
    let (cfg, ns, sampler) = fixture(seed);
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };
    let spec = ClosedLoopSpec {
        kind: lambda_fs::namespace::OpKind::Read,
        n_clients: 24,
        n_vms: 2,
        ops_per_client: 120,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("micro-read", seed, &params, spec.n_clients, spec.n_vms);
    let mut rec =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), meta);
    let mut rng = Rng::new(cfg.seed ^ 0xc10);
    driver::run_closed_loop(&mut rec, &spec, &ns, &sampler, &mut rng);
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();

    let m_rep = replay_into(
        LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms),
        &trace,
        &mut Rng::new(cfg.seed ^ 0xc10),
    );
    assert_eq!(m_rec.fingerprint(), m_rep.fingerprint(), "closed-loop round trip diverged");
}

/// `submit_batch` ≡ `submit`, for λFS' amortized-routing override and
/// for the default scalar-loop implementation every baseline inherits:
/// the batched open-loop driver reproduces the scalar driver's
/// `RunMetrics::fingerprint` (outcome ledger included) bit for bit.
#[test]
fn submit_batch_fingerprint_identical_to_scalar_all_systems() {
    let (cfg, ns, sampler) = fixture(51);
    // A target that does not divide the client count exercises ragged
    // tail batches.
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(5, 777.0),
        mix: OpMix::spotify(),
        n_clients: 48,
        n_vms: 2,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    };

    fn pair<S: MetadataService>(
        mut scalar: S,
        mut batched: S,
        spec: &OpenLoopSpec,
        ns: &Namespace,
        sampler: &HotspotSampler,
        seed: u64,
    ) -> (RunMetrics, RunMetrics) {
        let mut r1 = Rng::new(seed);
        driver::run_open_loop(&mut scalar, spec, ns, sampler, &mut r1);
        let mut r2 = Rng::new(seed);
        driver::run_open_loop_batched(&mut batched, spec, ns, sampler, &mut r2);
        (scalar.into_metrics(), batched.into_metrics())
    }

    // The contract is pinned on outcome_fingerprint(), the superset
    // digest: base run state AND the per-op outcome ledger must agree.
    fn check(a: &RunMetrics, b: &RunMetrics, what: &str) {
        assert_eq!(a.fingerprint(), b.fingerprint(), "{what}: base run state diverged");
        assert_eq!(
            a.outcome_fingerprint(),
            b.outcome_fingerprint(),
            "{what}: outcome ledger diverged"
        );
        assert_eq!(a.cold_starts + a.warm_ops, a.completed_ops, "{what}: conservation");
    }

    let mk_lfs = || LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    let (a, b) = pair(mk_lfs(), mk_lfs(), &spec, &ns, &sampler, 0xb47c);
    check(&a, &b, "λFS batch override");

    let mk_hops = || HopsFs::new(cfg.clone(), ns.clone(), 128.0, false);
    let (a, b) = pair(mk_hops(), mk_hops(), &spec, &ns, &sampler, 0xb47d);
    check(&a, &b, "HopsFS");

    let mk_hc = || HopsFs::new(cfg.clone(), ns.clone(), 128.0, true);
    let (a, b) = pair(mk_hc(), mk_hc(), &spec, &ns, &sampler, 0xb47e);
    check(&a, &b, "HopsFS+Cache");

    let mk_ceph = || CephFs::new(cfg.clone(), ns.clone(), 128.0);
    let (a, b) = pair(mk_ceph(), mk_ceph(), &spec, &ns, &sampler, 0xb47f);
    check(&a, &b, "CephFS");

    let mk_inf = || InfiniCacheMds::new(cfg.clone(), ns.clone(), 8);
    let (a, b) = pair(mk_inf(), mk_inf(), &spec, &ns, &sampler, 0xb480);
    check(&a, &b, "InfiniCache");

    use lambda_fs::baselines::{IndexFs, LambdaIndexFs};
    let mk_idx = || IndexFs::new(cfg.clone(), ns.clone(), 4, 112.0);
    let (a, b) = pair(mk_idx(), mk_idx(), &spec, &ns, &sampler, 0xb481);
    check(&a, &b, "IndexFS");

    let mk_lidx = || LambdaIndexFs::new(cfg.clone(), ns.clone(), 8, 64.0);
    let (a, b) = pair(mk_lidx(), mk_lidx(), &spec, &ns, &sampler, 0xb482);
    check(&a, &b, "λIndexFS");
}

/// Outcome-ledger sanity on a real λFS run: conservation, cache
/// accounting bounded by completions, retry histogram totals, and
/// per-deployment counts summing to the op count.
#[test]
fn outcome_counters_conserved_on_lambdafs_run() {
    let m = run_lambdafs_open(77);
    assert!(m.completed_ops > 0);
    assert_eq!(m.cold_starts + m.warm_ops, m.completed_ops);
    assert!(m.cold_starts > 0, "a cold-started fleet records cold starts");
    assert!(m.cache_hits + m.cache_misses <= m.completed_ops);
    assert!(m.cache_hits > 0, "hot Spotify reads hit the cache");
    assert_eq!(m.retry_hist.iter().sum::<u64>(), m.completed_ops);
    assert_eq!(m.per_deployment_ops.iter().sum::<u64>(), m.completed_ops);
}

/// A fixed-latency mock: saturates under an open-loop schedule when
/// `per_op_ms` exceeds the per-client service budget.
struct Fixed {
    metrics: RunMetrics,
    per_op_ms: f64,
}

impl Fixed {
    fn new(per_op_ms: f64) -> Fixed {
        Fixed { metrics: RunMetrics::new(), per_op_ms }
    }
}

impl MetadataService for Fixed {
    fn submit(
        &mut self,
        req: lambda_fs::systems::Request<'_>,
        _rng: &mut Rng,
    ) -> lambda_fs::systems::Completion {
        lambda_fs::systems::Completion::unstamped(
            req.at + time::from_ms(self.per_op_ms),
            lambda_fs::systems::Outcome::warm(0),
        )
    }
    fn on_second(&mut self, _s: usize) {}
    fn metrics_mut(&mut self) -> &mut RunMetrics {
        &mut self.metrics
    }
    fn into_metrics(self) -> RunMetrics {
        self.metrics
    }
}

/// The ROADMAP-known trace refinement, closed: recording captures the
/// *intended* (pre-rollover) slots, so a trace recorded from a saturated
/// system carries the pure offered schedule — and still replays into the
/// recording system bit for bit.
#[test]
fn record_under_saturation_keeps_pure_slots() {
    let params = NamespaceParams { n_dirs: 128, ..Default::default() };
    let mut rng = Rng::new(31);
    let ns = generate(&params, &mut rng);
    let sampler = HotspotSampler::new(&ns, 1.3, &mut rng);
    // 8 clients × 10 ops/s capacity against a 600 ops/s schedule: the
    // run saturates hard (realized issue times sprawl far past 3 s).
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(3, 600.0),
        mix: OpMix::spotify(),
        n_clients: 8,
        n_vms: 1,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("saturated", 31, &params, spec.n_clients, spec.n_vms);
    let mut rec = Recorder::new(Fixed::new(100.0), meta);
    let mut drv_rng = Rng::new(0x5a7);
    driver::run_open_loop(&mut rec, &spec, &ns, &sampler, &mut drv_rng);
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();
    assert!(
        m_rec.last_completion_us > 10 * time::SEC,
        "the recording system really was saturated ({})",
        m_rec.last_completion_us
    );

    // Pure slots: every recorded op timestamp sits inside the 3 s
    // schedule, at exactly the uniform slot the generator intended.
    let mut per_second = [0u64; 3];
    for ev in &trace.events {
        if let TraceEvent::Op { at, .. } = *ev {
            assert!(at < 3 * time::SEC, "realized (rolled-over) time leaked into trace: {at}");
            per_second[(at / time::SEC) as usize] += 1;
        }
    }
    for (s, &n) in per_second.iter().enumerate() {
        assert_eq!(n, 600, "second {s} carries the full offered load");
        for i in 0..n {
            let expect = s as u64 * time::SEC + i * time::SEC / n;
            assert!(
                trace
                    .events
                    .iter()
                    .any(|e| matches!(e, TraceEvent::Op { at, .. } if *at == expect)),
                "slot {expect} missing in second {s}"
            );
        }
    }

    // Round trip: replaying into a fresh identical (slow) system
    // reproduces the saturated run bit for bit...
    let m_rep = replay_into(Fixed::new(100.0), &trace, &mut Rng::new(0x5a7));
    assert_eq!(m_rec.fingerprint(), m_rep.fingerprint(), "saturated round trip diverged");
    assert_eq!(m_rec.outcome_fingerprint(), m_rep.outcome_fingerprint());

    // ...while a fast system replaying the same trace sees the pure
    // schedule and finishes on it, instead of inheriting the slow
    // system's throttling.
    let m_fast = replay_into(Fixed::new(2.0), &trace, &mut Rng::new(0x5a7));
    assert_eq!(m_fast.completed_ops, 1_800);
    assert!(
        m_fast.last_completion_us < 4 * time::SEC,
        "fast replay stays on schedule ({})",
        m_fast.last_completion_us
    );
}

/// The generational arena reproduces the retained pre-arena platform
/// (`faas::reference::ReferencePlatform`) command for command: identical
/// placement ready-times (and therefore identical RNG draw sequences),
/// identical live sets in iteration order, identical stats counters, and
/// billing totals equal to float tolerance — over randomized schedules
/// that mix placements, fault kills, capacity pressure, and idle
/// reclamation. This is the "fingerprints unchanged by the arena
/// refactor" contract at the substrate level, in the same spirit as the
/// calendar-queue ≡ `HeapQueue` differential.
#[test]
fn arena_platform_matches_reference_semantics() {
    for trial in 0..6u64 {
        let base = SystemConfig::default();
        let mut faas = base.faas.clone();
        let mut lcfg = base.lambda_fs.clone();
        lcfg.n_deployments = 4;
        // Trials 0-2 run uncapped; 3-5 run under a tight vCPU budget so
        // capacity evictions (and thus slot recycling) fire constantly.
        if trial >= 3 {
            faas.vcpu_limit = 6.25 * 3.0 / lcfg.max_vcpu_fraction;
        }
        // Short idle deadline: reclamation happens inside the trial.
        lcfg.idle_reclaim_ms = 50.0;

        let mut arena = Platform::new(faas.clone(), lcfg.clone());
        let mut refp = ReferencePlatform::new(faas, lcfg);
        let seed = 0xa12e ^ trial;
        let mut ra = Rng::new(seed);
        let mut rr = Rng::new(seed);
        let mut decide = Rng::new(0xd1f ^ trial);

        for step in 0..1_200u64 {
            let now = step * 2_000; // 2 ms per step
            match decide.below(10) {
                0..=6 => {
                    let dep = (decide.below(4)) as u32;
                    let (ia, ta, ca) = arena.place_http_traced(dep, now, &mut ra);
                    let (ir, tr, cr) = refp.place_http_traced(dep, now, &mut rr);
                    assert_eq!(ta, tr, "trial {trial} step {step}: ready time diverged");
                    // The frozen reference keeps the binary cold/warm
                    // attribution; under the default (ladder-off) config
                    // the arena's tier collapses to the same bit.
                    assert_eq!(
                        ca.is_cold(),
                        cr,
                        "trial {trial} step {step}: cold attribution diverged"
                    );
                    assert_eq!(arena.instance(ia).deployment, refp.instance(ir).deployment);
                    // Bill the placement identically on both sides.
                    arena.bill(ia, ta, ta + 700);
                    refp.instance_mut(ir).bill(ta, ta + 700);
                }
                7 => {
                    // Fault-inject: kill the oldest live instance of a
                    // deployment (the fig15 selection rule).
                    let dep = (decide.below(4)) as u32;
                    let va = arena.deployment_instances(dep).next();
                    let vr = refp.deployment_instances(dep).first().copied();
                    assert_eq!(va.is_some(), vr.is_some(), "trial {trial}: membership diverged");
                    if let (Some(va), Some(vr)) = (va, vr) {
                        assert_eq!(arena.instance(va).born, refp.instance(vr).born);
                        arena.kill(va, now, false);
                        refp.kill(vr, now, false);
                    }
                }
                8 => {
                    let dep = (decide.below(4)) as u32;
                    let wa = arena.warm_instance(dep, now);
                    let wr = refp.warm_instance(dep, now);
                    assert_eq!(wa.is_some(), wr.is_some());
                    if let (Some(wa), Some(wr)) = (wa, wr) {
                        assert_eq!(arena.instance(wa).born, refp.instance(wr).born);
                        assert_eq!(
                            arena.cpu_earliest_start(wa, now),
                            refp.instance(wr).cpu.earliest_start(now)
                        );
                    }
                }
                _ => {
                    // Second-boundary housekeeping.
                    arena.promote_warm(now);
                    refp.promote_warm(now);
                    assert_eq!(arena.reclaim_idle(now).len(), refp.reclaim_idle(now).len());
                    let (ba, br) = (arena.busy_gb_seconds(now), refp.busy_gb_seconds(now));
                    assert!((ba - br).abs() <= 1e-6 * br.abs().max(1.0), "{ba} vs {br}");
                    assert_eq!(arena.total_requests(), refp.total_requests());
                }
            }
            assert_eq!(arena.live_instances(), refp.live_instances(), "trial {trial} step {step}");
            // The live sets match pairwise in iteration order (the order
            // every scan and roster consumes).
            let a: Vec<(u64, u32)> = arena
                .live_iter()
                .map(|i| (arena.instance(i).born, arena.instance(i).deployment))
                .collect();
            let r: Vec<(u64, u32)> = refp
                .instances
                .iter()
                .filter(|i| i.alive())
                .map(|i| (i.born, i.deployment))
                .collect();
            assert_eq!(a, r, "trial {trial} step {step}: live iteration order diverged");
        }

        let (sa, sr) = (arena.stats(), refp.stats());
        assert_eq!(sa.cold_starts, sr.cold_starts, "trial {trial}");
        assert_eq!(sa.kills, sr.kills, "trial {trial}");
        assert_eq!(sa.idle_reclaims, sr.idle_reclaims, "trial {trial}");
        assert_eq!(sa.evictions_for_capacity, sr.evictions_for_capacity, "trial {trial}");
        assert_eq!(sa.rejected_at_capacity, sr.rejected_at_capacity, "trial {trial}");
        if trial >= 3 {
            assert!(sa.recycled_slots > 0, "capped trial {trial} must recycle slots");
            assert!(
                arena.arena_slots() < arena.spawned_total() as usize,
                "arena memory must stay below instances-ever under churn"
            );
        }
    }
}

/// Stale ids from killed instances are rejected at the public API even
/// after their slot has been recycled — never aliased to the new
/// occupant.
#[test]
fn stale_instance_id_rejected_after_slot_recycling() {
    let c = SystemConfig::default();
    let mut p = Platform::new(c.faas, c.lambda_fs);
    let mut rng = Rng::new(17);
    let (id, ready) = p.place_http(0, 0, &mut rng);
    p.promote_warm(ready);
    p.kill(id, ready + 1, false);
    assert!(p.get(id).is_none(), "killed id is stale");
    let (id2, _) = p.place_http(0, ready + 10, &mut rng);
    assert_eq!(id2.slot(), id.slot(), "LIFO free list recycles the slot");
    assert_ne!(id2, id, "generation differs");
    assert!(p.get(id).is_none(), "stale id stays rejected after recycling");
    assert!(!p.is_live(id) && p.is_live(id2));
    assert!(!p.warm_at(id, ready + 1_000_000));
    assert!(id < id2, "spawn-seq ordering is monotonic across recycling");
}

/// Kill-heavy determinism: a container-churn trace (CFS-style deep-path
/// create/stat/unlink bursts) replayed into λFS under a fig15-style kill
/// schedule — the regime where instance ids die and slots recycle
/// mid-run. Same seed → bit-identical `fingerprint` and
/// `outcome_fingerprint`; the run must actually exercise recycling.
#[test]
fn kill_heavy_container_churn_deterministic() {
    fn run(seed: u64) -> (RunMetrics, u64, u64, usize) {
        let mut cfg = SystemConfig::default();
        cfg.seed = seed;
        cfg.lambda_fs.n_deployments = 8;
        let params = NamespaceParams { n_dirs: 256, files_per_dir: 16, ..Default::default() };
        let mut ns_rng = Rng::new(seed);
        let ns = generate(&params, &mut ns_rng);
        let spec = ContainerChurnSpec::at_scale(0.002); // 20 s, ~300 ops/s
        let meta = TraceMeta::new("churn-kill", seed, &params, 48, 2);
        let mut trace_rng = Rng::new(seed ^ 0xc4a);
        let trace = synth::container_churn(&spec, &ns, meta, &mut trace_rng);

        let mut sys = LambdaFs::new(cfg, ns, 48, 2);
        for (i, s) in (2..spec.duration_s).step_by(2).enumerate() {
            sys.schedule_kill(s, (i as u32) % 8);
        }
        replay(&mut sys, &trace, &mut Rng::new(seed ^ 0x5eed));
        let stats = sys.platform().stats();
        let slots = sys.platform().arena_slots();
        let m = sys.into_metrics();
        (m, stats.kills, stats.recycled_slots, slots)
    }

    let (a, kills_a, recycled_a, _) = run(4242);
    let (b, kills_b, _, _) = run(4242);
    assert_eq!(a.fingerprint(), b.fingerprint(), "kill-heavy runs diverged");
    assert_eq!(a.outcome_fingerprint(), b.outcome_fingerprint(), "outcome ledgers diverged");
    assert_eq!(kills_a, kills_b);
    assert!(kills_a >= 5, "the kill schedule actually fired: {kills_a}");
    assert!(recycled_a > 0, "the run must recycle killed slots: {recycled_a}");
    assert_eq!(a.cold_starts + a.warm_ops, a.completed_ops, "conservation under churn");

    let (c, ..) = run(2424);
    assert_ne!(a.fingerprint(), c.fingerprint(), "digest insensitive to seed");
}

/// The sampling-substrate determinism contract at the public-API level:
/// one RNG draw per sample for every table-driven distribution. Forked
/// component streams stay aligned across refactors only if per-sample
/// draw counts are fixed, so this is load-bearing for record→replay.
#[test]
fn sampling_substrate_consumes_one_draw_per_sample() {
    use lambda_fs::util::dist::{Alias, Exp, LogNormal, Pareto, Zipf};
    fn one_draw(label: &str, mut sample: impl FnMut(&mut Rng)) {
        let mut a = Rng::new(0x0d1a);
        let mut b = Rng::new(0x0d1a);
        for _ in 0..32 {
            sample(&mut a);
            b.next_u64();
        }
        for _ in 0..4 {
            assert_eq!(a.next_u64(), b.next_u64(), "{label}: != one draw per sample");
        }
    }
    let net = lambda_fs::rpc::NetModel::new(SystemConfig::default().net);
    one_draw("NetModel::tcp_hop", |r| {
        net.tcp_hop(r);
    });
    one_draw("NetModel::http_leg", |r| {
        net.http_leg(r);
    });
    let p = Pareto::new(25_000.0, 2.0);
    one_draw("Pareto", |r| {
        p.sample(r);
    });
    let e = Exp::new(2.0);
    one_draw("Exp", |r| {
        e.sample(r);
    });
    let ln = LogNormal::from_median(8.0, 0.6);
    one_draw("LogNormal", |r| {
        ln.sample(r);
    });
    let z = Zipf::new(4096, 1.3);
    one_draw("Zipf", |r| {
        z.sample(r);
    });
    let a = Alias::new(&[3.0, 1.0, 0.5]);
    one_draw("Alias", |r| {
        a.sample(r);
    });
    let mix = OpMix::spotify();
    one_draw("OpMix::sample_kind", |r| {
        mix.sample_kind(r);
    });
}

/// The integer-bucketed histogram migration, pinned at the system level:
/// latency counts conserve across read/write splits, quantiles stay
/// ordered and bounded by observed extremes, and the CDF terminates at 1.
#[test]
fn latency_histograms_consistent_after_integer_migration() {
    let m = run_lambdafs_open(1234);
    assert!(m.completed_ops > 0);
    assert_eq!(m.all_lat.count(), m.completed_ops);
    assert_eq!(m.read_lat.count() + m.write_lat.count(), m.all_lat.count());
    for h in [&m.read_lat, &m.write_lat, &m.all_lat] {
        assert!(h.p50() <= h.p99(), "quantiles ordered");
        assert!(h.min() <= h.mean() && h.mean() <= h.max(), "mean within extremes");
        assert!(h.quantile(1.0) <= h.max() && h.quantile(0.0) >= h.min());
        let cdf = h.cdf();
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9, "cdf completes");
    }
}

/// A composite fault plan touching every chaos category: instance kills,
/// a deployment blackout, a coordinator blackout (writes only), a
/// client-VM↔deployment partition held to the end of the run, degraded
/// links, a straggler burst, and invalidation-ACK disruption.
fn composite_plan() -> ChaosPlan {
    ChaosPlan {
        n_vms: 2,
        kills: vec![
            KillEvent { second: 2, deployment: 0 },
            KillEvent { second: 4, deployment: 3 },
        ],
        blackouts: vec![
            Blackout { from_s: 3, to_s: 5, deployment: Some(1) },
            Blackout { from_s: 5, to_s: 6, deployment: None },
        ],
        partitions: vec![Partition { from_s: 2, to_s: u32::MAX, vm: 1, deployment: 2 }],
        delays: vec![DelayWindow { from_s: 0, to_s: 8, tcp_mult: 10.0, http_mult: 10.0 }],
        stragglers: vec![StragglerBurst { from_s: 0, to_s: 8, prob: 0.15, factor: 30.0 }],
        acks: vec![AckChaos { from_s: 0, to_s: 8, drop_prob: 0.3, delay_ms: 4.0 }],
    }
}

/// Seeded chaos is part of the determinism contract: the same seed and
/// the same plan reproduce the run bit for bit, fault handling included
/// — and nothing is lost or double-counted on the way
/// (`completed_ops + gave_up` accounts for every submitted op).
#[test]
fn chaos_run_twice_fingerprint_identical() {
    fn run(seed: u64) -> RunMetrics {
        let (cfg, ns, sampler) = fixture(seed);
        let spec = OpenLoopSpec {
            schedule: ThroughputSchedule::constant(8, 800.0),
            mix: OpMix::spotify(),
            n_clients: 64,
            n_vms: 2,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        sys.install_chaos(&composite_plan());
        let mut rng = Rng::new(cfg.seed ^ 0xd0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    }

    let a = run(1234);
    let b = run(1234);
    assert_eq!(a.fingerprint(), b.fingerprint(), "chaotic runs diverged");
    assert_eq!(a.outcome_fingerprint(), b.outcome_fingerprint(), "chaos ledgers diverged");
    // The plan actually bit.
    assert!(a.timeouts > 0, "composite plan produced no timeouts");
    assert!(a.gave_up > 0, "the held partition produced no give-ups");
    // Conservation under chaos: every op either completed or gave up,
    // and completed ops still split exactly into cold + warm.
    assert_eq!(a.failed_ops, a.gave_up, "give-ups are the only failures");
    assert_eq!(a.cold_starts + a.warm_ops, a.completed_ops, "conservation under chaos");
    assert_eq!(a.completed_ops + a.gave_up, 8 * 800, "no op vanished");
    // A different seed moves the chaotic fingerprint too.
    let c = run(4321);
    assert_ne!(a.fingerprint(), c.fingerprint(), "chaos digest insensitive to seed");
}

/// Chaos runs record→replay bit-identically: the plan rides in the trace
/// header (format v2), the replayer reinstalls it, and the dedicated
/// chaos stream (seeded by system seed ⊕ plan digest) realigns draws.
#[test]
fn chaos_record_replay_bit_identical() {
    let seed = 2025u64;
    let (cfg, ns, sampler) = fixture(seed);
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(8, 700.0),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("spotify-chaos", seed, &params, spec.n_clients, spec.n_vms);

    let mut rec =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), meta);
    rec.install_chaos(&composite_plan());
    let mut rng = Rng::new(cfg.seed ^ 0xabce);
    driver::run_open_loop(&mut rec, &spec, &ns, &sampler, &mut rng);
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();
    assert!(m_rec.timeouts > 0 && m_rec.gave_up > 0, "recording saw chaos");
    assert_eq!(trace.chaos, composite_plan(), "plan captured into the trace");

    // Binary round trip carries the plan (format v2).
    let decoded = Trace::decode(&trace.encode()).expect("decode chaotic trace");
    assert_eq!(trace, decoded);

    // The replayer reinstalls the plan from the header: bit-identical.
    let m_rep = replay_into(
        LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms),
        &decoded,
        &mut Rng::new(cfg.seed ^ 0xabce),
    );
    assert_eq!(
        m_rec.fingerprint(),
        m_rep.fingerprint(),
        "chaotic record→replay must reproduce the run bit for bit"
    );
    assert_eq!(m_rec.outcome_fingerprint(), m_rep.outcome_fingerprint());
    assert_eq!(m_rec.timeouts, m_rep.timeouts);
    assert_eq!(m_rec.gave_up, m_rep.gave_up);
}

/// The zero-overhead contract: a system with `ChaosPlan::none()`
/// installed is draw-for-draw identical to one with no plan at all —
/// chaos hooks must not perturb clean runs.
#[test]
fn empty_chaos_plan_is_identity() {
    let baseline = run_lambdafs_open(1234);
    let (cfg, ns, sampler) = fixture(1234);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(8, 800.0),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    };
    let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    sys.install_chaos(&ChaosPlan::none());
    let mut rng = Rng::new(cfg.seed ^ 0xd0);
    driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
    let m = sys.into_metrics();
    assert_eq!(baseline.fingerprint(), m.fingerprint(), "empty plan perturbed λFS");
    assert_eq!(baseline.outcome_fingerprint(), m.outcome_fingerprint());
    assert_eq!(m.timeouts, 0);
    assert_eq!(m.gave_up, 0);

    // Baselines honor the same contract through the shared hook.
    let run_hops = |chaos: bool| -> RunMetrics {
        let mut sys = HopsFs::new(cfg.clone(), ns.clone(), 128.0, true);
        if chaos {
            sys.install_chaos(&ChaosPlan::none());
        }
        let mut rng = Rng::new(cfg.seed ^ 0xb0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    assert_eq!(run_hops(false).fingerprint(), run_hops(true).fingerprint());

    let run_ceph = |chaos: bool| -> RunMetrics {
        let mut sys = CephFs::new(cfg.clone(), ns.clone(), 128.0);
        if chaos {
            sys.install_chaos(&ChaosPlan::none());
        }
        let mut rng = Rng::new(cfg.seed ^ 0xce);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    assert_eq!(run_ceph(false).fingerprint(), run_ceph(true).fingerprint());
}

/// The telemetry zero-overhead contract (PR-7 twin of the empty-chaos
/// identity above): arming the per-second timeline sampler consumes no
/// RNG draws and touches no simulated state, so a telemetry-on run is
/// fingerprint-identical — base digest AND outcome ledger — to the same
/// seed's telemetry-off run, for λFS and the baselines alike.
#[test]
fn telemetry_sampler_is_zero_overhead() {
    use lambda_fs::telemetry::Timeline;
    let (cfg, ns, sampler) = fixture(1234);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(8, 800.0),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    };

    // λFS (driver stream ^ 0xd0, the same as run_lambdafs_open).
    let run_lfs = |telemetry: bool| -> (RunMetrics, Option<Timeline>) {
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        if telemetry {
            assert!(sys.install_telemetry(Timeline::new("lambdafs", 8)));
        }
        let mut rng = Rng::new(cfg.seed ^ 0xd0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        let tl = sys.take_telemetry();
        (sys.into_metrics(), tl)
    };
    let (off, none) = run_lfs(false);
    let (on, tl) = run_lfs(true);
    assert!(none.is_none(), "nothing to take when never armed");
    let tl = tl.expect("armed sampler is retrievable");
    assert!(!tl.samples.is_empty(), "the sampler actually captured seconds");
    assert_eq!(off.fingerprint(), on.fingerprint(), "telemetry perturbed λFS");
    assert_eq!(off.outcome_fingerprint(), on.outcome_fingerprint(), "ledger diverged");

    // HopsFS+Cache (^ 0xb0) and CephFS (^ 0xce) honor the same contract.
    let run_hops = |telemetry: bool| -> RunMetrics {
        let mut sys = HopsFs::new(cfg.clone(), ns.clone(), 128.0, true);
        if telemetry {
            assert!(sys.install_telemetry(Timeline::new("hopsfs+cache", 1)));
        }
        let mut rng = Rng::new(cfg.seed ^ 0xb0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    assert_eq!(
        run_hops(false).outcome_fingerprint(),
        run_hops(true).outcome_fingerprint(),
        "telemetry perturbed HopsFS"
    );

    let run_ceph = |telemetry: bool| -> RunMetrics {
        let mut sys = CephFs::new(cfg.clone(), ns.clone(), 128.0);
        if telemetry {
            assert!(sys.install_telemetry(Timeline::new("cephfs", 1)));
        }
        let mut rng = Rng::new(cfg.seed ^ 0xce);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    assert_eq!(
        run_ceph(false).outcome_fingerprint(),
        run_ceph(true).outcome_fingerprint(),
        "telemetry perturbed CephFS"
    );
}

/// The span layer's conservation invariant at the run level: every
/// completed op's phase breakdown sums to its end-to-end latency, so the
/// per-phase totals sum exactly to the all-ops latency total — and the
/// per-phase histograms each hold one sample per completed op.
#[test]
fn phase_breakdowns_conserve_e2e_latency() {
    use lambda_fs::telemetry::Phase;
    let m = run_lambdafs_open(1234);
    assert!(m.completed_ops > 0);
    let phase_total: u64 = Phase::ALL.iter().map(|&p| m.phase_hist(p).sum_us()).sum();
    assert_eq!(phase_total, m.all_lat.sum_us(), "phase sums must conserve e2e latency");
    for p in Phase::ALL {
        assert_eq!(
            m.phase_hist(p).count(),
            m.completed_ops,
            "phase {} stamped on every op",
            p.name()
        );
    }
    // The shares are a partition of the attributed latency.
    let share_sum: f64 = Phase::ALL.iter().map(|&p| m.phase_share(p)).sum();
    assert!((share_sum - 1.0).abs() < 1e-9, "shares sum to 1: {share_sum}");
    // A Spotify λFS run touches the queue, exec, net, and store phases.
    for p in [Phase::Queue, Phase::Exec, Phase::Net, Phase::Store] {
        assert!(m.phase_hist(p).sum_us() > 0, "phase {} never attributed", p.name());
    }
}

/// Record→replay stays bit-identical with the sampler armed on both
/// sides, and the two samplers capture fingerprint-identical timelines.
#[test]
fn record_replay_bit_identical_with_sampler_armed() {
    use lambda_fs::telemetry::Timeline;
    // Mirror trace_record_replay_bit_identical_spotify with the sampler
    // armed on both sides: recording a run with telemetry on still
    // captures the identical trace, the replay reproduces the identical
    // fingerprints, and both samplers saw the identical per-second story.
    let seed = 2024u64;
    let (cfg, ns, sampler) = fixture(seed);
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };
    let mut sched_rng = Rng::new(seed ^ 0x5c);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::pareto_bursty(6, 3, 600.0, 2.0, 7.0, &mut sched_rng),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("spotify", seed, &params, spec.n_clients, spec.n_vms);

    let mut rec =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), meta);
    assert!(rec.install_telemetry(Timeline::new("lambdafs", 8)), "recorder forwards the hook");
    let mut rng = Rng::new(cfg.seed ^ 0xabcd);
    driver::run_open_loop(&mut rec, &spec, &ns, &sampler, &mut rng);
    let tl_rec = rec.take_telemetry().expect("recording sampler retrievable");
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();

    let mut replayed = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    assert!(replayed.install_telemetry(Timeline::new("lambdafs", 8)));
    replay(&mut replayed, &trace, &mut Rng::new(cfg.seed ^ 0xabcd));
    let tl_rep = replayed.take_telemetry().expect("replay sampler retrievable");
    let m_rep = replayed.into_metrics();

    assert_eq!(m_rec.fingerprint(), m_rep.fingerprint(), "sampler broke record→replay");
    assert_eq!(m_rec.outcome_fingerprint(), m_rep.outcome_fingerprint());
    assert_eq!(
        tl_rec.fingerprint(),
        tl_rep.fingerprint(),
        "record and replay samplers captured different timelines"
    );
    // The binary timeline section round-trips bit for bit too.
    let decoded = Timeline::decode(&tl_rec.encode()).expect("timeline decodes");
    assert_eq!(decoded.fingerprint(), tl_rec.fingerprint());

    // And the armed recording still matches the unarmed baseline run
    // (zero-overhead, composed with the recording path).
    let mut bare =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), {
            TraceMeta::new("spotify", seed, &params, spec.n_clients, spec.n_vms)
        });
    let mut rng = Rng::new(cfg.seed ^ 0xabcd);
    driver::run_open_loop(&mut bare, &spec, &ns, &sampler, &mut rng);
    let (bare_sys, bare_trace) = bare.into_parts();
    assert_eq!(bare_trace, trace, "telemetry must not change the captured trace");
    assert_eq!(bare_sys.into_metrics().fingerprint(), m_rec.fingerprint());
}

/// Driving the *same closed-loop workload* through both queue
/// implementations yields the same submission order end to end.
#[test]
fn closed_loop_schedule_differential() {
    // Simulate the closed-loop driver's queue usage pattern: clients
    // reschedule themselves at their (deterministic) completion times.
    let service = |c: u64, t: u64| 500 + ((c * 2654435761 + t) % 3_000);
    let run_with = |use_cal: bool| -> Vec<(u64, u64)> {
        let mut cal: EventQueue<u64> = EventQueue::new();
        let mut heap: HeapQueue<u64> = HeapQueue::new();
        for c in 0..64u64 {
            if use_cal {
                cal.schedule_at(c * 100, c);
            } else {
                heap.schedule_at(c * 100, c);
            }
        }
        let mut order = Vec::new();
        let mut remaining = vec![50u32; 64];
        loop {
            let s = if use_cal { cal.pop() } else { heap.pop() };
            let Some(s) = s else { break };
            order.push((s.at, s.event));
            let c = s.event as usize;
            remaining[c] -= 1;
            if remaining[c] > 0 {
                let done = s.at + service(s.event, s.at);
                if use_cal {
                    cal.schedule_at(done, s.event);
                } else {
                    heap.schedule_at(done, s.event);
                }
            }
        }
        order
    };
    assert_eq!(run_with(true), run_with(false));
}

/// One λFS system per shard of `plan`: shard-forked seeds
/// (`ShardPlan::shard_seed`), client-slice widths, and an evenly divided
/// vCPU budget (shards model disjoint slices of one cluster).
fn sharded_lambdafs_fleet(
    cfg: &SystemConfig,
    ns: &Namespace,
    plan: &ShardPlan,
    n_vms: u32,
) -> Vec<LambdaFs> {
    (0..plan.n_shards)
        .map(|i| {
            let mut c = cfg.clone();
            c.seed = ShardPlan::shard_seed(cfg.seed, i);
            c.faas.vcpu_limit = cfg.faas.vcpu_limit / f64::from(plan.n_shards);
            LambdaFs::new(c, ns.clone(), plan.slice(i).len() as u32, n_vms)
        })
        .collect()
}

fn sharded_spec() -> OpenLoopSpec {
    OpenLoopSpec {
        schedule: ThroughputSchedule::constant(8, 800.0),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    }
}

/// Run the sharded Spotify fixture on `exec`; returns the per-shard
/// metrics and the merged ledger.
fn run_sharded(seed: u64, n_shards: u32, exec: &impl Executor) -> (Vec<RunMetrics>, RunMetrics) {
    let (cfg, ns, sampler) = fixture(seed);
    let spec = sharded_spec();
    let plan = ShardPlan::new(n_shards, spec.n_clients, &cfg.net);
    let mut systems = sharded_lambdafs_fleet(&cfg, &ns, &plan, spec.n_vms);
    let mut root = Rng::new(cfg.seed ^ 0xd0);
    run_open_loop_sharded(&mut systems, &spec, &ns, &sampler, &mut root, &plan, exec);
    let per_shard: Vec<RunMetrics> = systems.into_iter().map(LambdaFs::into_metrics).collect();
    let mut merged = per_shard[0].clone();
    for m in &per_shard[1..] {
        merged.merge(m);
    }
    (per_shard, merged)
}

/// Sharded determinism pin 1: same seed → bit-identical sharded run,
/// per shard and merged, with the conservation invariants intact and a
/// different seed actually moving the digest.
#[test]
fn sharded_run_twice_fingerprint_identical() {
    let exec = ThreadPool::with_default_workers();
    let (shards_a, a) = run_sharded(1234, 4, &exec);
    let (shards_b, b) = run_sharded(1234, 4, &exec);
    assert_eq!(shards_a.len(), 4);
    for (i, (x, y)) in shards_a.iter().zip(&shards_b).enumerate() {
        assert_eq!(x.fingerprint(), y.fingerprint(), "shard {i} diverged");
        assert_eq!(x.outcome_fingerprint(), y.outcome_fingerprint(), "shard {i} ledger");
        assert!(x.completed_ops > 0, "shard {i} sat idle");
    }
    assert_eq!(a.fingerprint(), b.fingerprint(), "merged sharded runs diverged");
    assert_eq!(a.outcome_fingerprint(), b.outcome_fingerprint());
    assert_eq!(a.cold_starts + a.warm_ops, a.completed_ops, "conservation survives merge");
    assert_eq!(a.completed_ops + a.gave_up, 8 * 800, "no op vanished across shards");
    let (_, c) = run_sharded(4321, 4, &exec);
    assert_ne!(a.fingerprint(), c.fingerprint(), "sharded digest insensitive to seed");
}

/// Sharded determinism pin 2: results are independent of the
/// worker-thread count by construction — `Sequential` and thread pools
/// of several widths produce bit-identical per-shard AND merged
/// fingerprints.
#[test]
fn sharded_thread_count_invariance() {
    let (base_shards, base) = run_sharded(77, 4, &Sequential);
    for workers in [1usize, 2, 4, 7] {
        let (shards, merged) = run_sharded(77, 4, &ThreadPool { workers });
        for (i, (x, y)) in base_shards.iter().zip(&shards).enumerate() {
            assert_eq!(
                x.fingerprint(),
                y.fingerprint(),
                "shard {i} diverged under {workers} workers"
            );
            assert_eq!(x.outcome_fingerprint(), y.outcome_fingerprint());
        }
        assert_eq!(
            base.fingerprint(),
            merged.fingerprint(),
            "{workers}-worker merge diverged from sequential"
        );
        assert_eq!(base.outcome_fingerprint(), merged.outcome_fingerprint());
    }
}

/// Sharded determinism pin 3: record→replay of a sharded λFS run is
/// bit-identical. Each shard records through its own `Recorder`; the
/// captured per-shard traces round-trip through the binary format and
/// replay through `replay_sharded` into a fresh same-seed fleet.
#[test]
fn sharded_record_replay_bit_identical() {
    let seed = 2026u64;
    let (cfg, ns, sampler) = fixture(seed);
    let spec = sharded_spec();
    let plan = ShardPlan::new(3, spec.n_clients, &cfg.net);
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };

    // Record: one Recorder-wrapped system per shard, live sharded run.
    let mut recorders: Vec<Recorder<LambdaFs>> =
        sharded_lambdafs_fleet(&cfg, &ns, &plan, spec.n_vms)
            .into_iter()
            .enumerate()
            .map(|(i, sys)| {
                let n = plan.slice(i as u32).len() as u32;
                let meta = TraceMeta::new("spotify-shard", seed, &params, n, 2);
                Recorder::new(sys, meta)
            })
            .collect();
    let mut root = Rng::new(cfg.seed ^ 0xd0);
    run_open_loop_sharded(
        &mut recorders,
        &spec,
        &ns,
        &sampler,
        &mut root,
        &plan,
        &ThreadPool::with_default_workers(),
    );
    let (rec_metrics, traces): (Vec<RunMetrics>, Vec<Trace>) = recorders
        .into_iter()
        .map(|r| {
            let (sys, trace) = r.into_parts();
            (sys.into_metrics(), trace)
        })
        .unzip();
    for (i, (m, t)) in rec_metrics.iter().zip(&traces).enumerate() {
        assert_eq!(t.n_ops(), m.completed_ops, "shard {i}: every submit captured");
        assert!(m.completed_ops > 0, "shard {i} sat idle");
    }

    // Binary round trip per shard.
    let decoded: Vec<Trace> = traces
        .iter()
        .map(|t| Trace::decode(&t.encode()).expect("decode shard trace"))
        .collect();
    assert_eq!(traces, decoded);

    // Replay into a fresh same-seed fleet: bit-identical per shard.
    let mut fresh = sharded_lambdafs_fleet(&cfg, &ns, &plan, spec.n_vms);
    replay_sharded(
        &mut fresh,
        &decoded,
        &plan,
        &mut Rng::new(cfg.seed ^ 0xd0),
        &ThreadPool::with_default_workers(),
    );
    for (i, (rec, sys)) in rec_metrics.iter().zip(fresh).enumerate() {
        let rep = sys.into_metrics();
        assert_eq!(
            rec.fingerprint(),
            rep.fingerprint(),
            "shard {i}: sharded record→replay must reproduce the run bit for bit"
        );
        assert_eq!(rec.outcome_fingerprint(), rep.outcome_fingerprint(), "shard {i} ledger");
    }
}

/// Sharded determinism pin 4: a single-shard plan reproduces the classic
/// sequential driver exactly — the sharded engine's op layout, RNG
/// forking, and rollover collapse to `driver::run_open_loop` when
/// `n_shards == 1` — over randomized (seed, rate, duration, fleet)
/// trials. The engine forks `shard/0` off the root and seeds the shard
/// system with `ShardPlan::shard_seed`, so the reference run mirrors
/// both derivations.
#[test]
fn sharded_single_shard_matches_sequential_driver() {
    for trial in 0..4u64 {
        let seed = 0x5a4d ^ (trial * 0x9e37);
        let mut lay = Rng::new(seed ^ 0x1a9);
        let duration = 3 + lay.below(4) as usize;
        let rate = 300.0 + lay.below(500) as f64;
        let n_clients = 16 + lay.below(64) as u32;
        let (cfg, ns, sampler) = fixture(seed);
        let spec = OpenLoopSpec {
            schedule: ThroughputSchedule::constant(duration, rate),
            mix: OpMix::spotify(),
            n_clients,
            n_vms: 2,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let plan = ShardPlan::new(1, spec.n_clients, &cfg.net);

        let mut fleet = sharded_lambdafs_fleet(&cfg, &ns, &plan, spec.n_vms);
        let mut root = Rng::new(seed ^ 0xd0);
        run_open_loop_sharded(&mut fleet, &spec, &ns, &sampler, &mut root, &plan, &Sequential);
        let sharded = fleet.pop().expect("one shard").into_metrics();

        // The reference: the sequential driver over a system built the
        // way the engine builds shard 0.
        let mut c = cfg.clone();
        c.seed = ShardPlan::shard_seed(cfg.seed, 0);
        let mut sys = LambdaFs::new(c, ns.clone(), spec.n_clients, spec.n_vms);
        let mut reference_root = Rng::new(seed ^ 0xd0);
        let mut r = reference_root.fork("shard/0");
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut r);
        let sequential = sys.into_metrics();

        assert!(sharded.completed_ops > 0, "trial {trial} sat idle");
        assert_eq!(
            sharded.fingerprint(),
            sequential.fingerprint(),
            "trial {trial}: single-shard engine diverged from the sequential driver"
        );
        assert_eq!(
            sharded.outcome_fingerprint(),
            sequential.outcome_fingerprint(),
            "trial {trial}: ledgers diverged"
        );
    }
}

/// Tier-ladder pin 1: the default config keeps the ladder OFF, so every
/// system's run stays in the pre-ladder fingerprint domain — the upper
/// rungs never fire, every λFS cold start is an ephemeral boot, and the
/// tier counters therefore never fold into the digest (the conditional
/// fold, unit-pinned in `metrics::run`). Run-twice identity holds for
/// λFS and the serverful baselines alike.
#[test]
fn ladder_off_default_keeps_pre_ladder_domain() {
    let a = run_lambdafs_open(1234);
    assert_eq!(a.pool_hits, 0, "ladder off: pool rung never fires");
    assert_eq!(a.restores, 0, "ladder off: restore rung never fires");
    assert_eq!(
        a.ephemeral_boots, a.cold_starts,
        "ladder off: every cold start is an ephemeral boot"
    );
    assert!(a.cold_starts > 0, "a cold-started fleet records cold starts");
    let b = run_lambdafs_open(1234);
    assert_eq!(a.outcome_fingerprint(), b.outcome_fingerprint(), "λFS ladder-off diverged");

    let (cfg, ns, sampler) = fixture(1234);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::constant(5, 500.0),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    };
    let run_hops = || -> RunMetrics {
        let mut sys = HopsFs::new(cfg.clone(), ns.clone(), 128.0, true);
        let mut rng = Rng::new(cfg.seed ^ 0xb0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    let h = run_hops();
    assert_eq!(h.pool_hits + h.restores + h.ephemeral_boots, h.cold_starts);
    assert_eq!(h.outcome_fingerprint(), run_hops().outcome_fingerprint(), "HopsFS diverged");

    let run_ceph = || -> RunMetrics {
        let mut sys = CephFs::new(cfg.clone(), ns.clone(), 128.0);
        let mut rng = Rng::new(cfg.seed ^ 0xce);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    let c = run_ceph();
    assert_eq!(c.cold_starts, 0, "serverful CephFS never cold-starts");
    assert_eq!(c.ephemeral_boots, 0);
    assert_eq!(c.outcome_fingerprint(), run_ceph().outcome_fingerprint(), "CephFS diverged");
}

/// Tier-ladder pin 2: a ladder-on run (reactive scale-out, kills seeding
/// checkpoints) is deterministic in the seed and conserves the tier
/// ledger — `pool_hits + restores + ephemeral_boots == cold_starts` —
/// with the first boots necessarily on the ephemeral rung.
#[test]
fn ladder_on_run_twice_fingerprint_identical() {
    fn run(seed: u64) -> RunMetrics {
        let (mut cfg, ns, sampler) = fixture(seed);
        cfg.faas.tier_ladder = true;
        let spec = OpenLoopSpec {
            schedule: ThroughputSchedule::constant(8, 800.0),
            mix: OpMix::spotify(),
            n_clients: 64,
            n_vms: 2,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        // Kills deposit checkpoints, so later cold starts can land on
        // the restore rung.
        for (i, s) in (1..8).step_by(2).enumerate() {
            sys.schedule_kill(s, (i as u32) % 8);
        }
        let mut rng = Rng::new(cfg.seed ^ 0xd0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    }
    let a = run(1234);
    let b = run(1234);
    assert_eq!(a.fingerprint(), b.fingerprint(), "ladder-on runs diverged");
    assert_eq!(a.outcome_fingerprint(), b.outcome_fingerprint(), "ladder-on ledgers diverged");
    assert_eq!(a.pool_hits + a.restores + a.ephemeral_boots, a.cold_starts, "tier conservation");
    assert_eq!(a.cold_starts + a.warm_ops, a.completed_ops, "outcome conservation");
    assert!(a.ephemeral_boots > 0, "first boots pay the ephemeral rung");
    let c = run(4321);
    assert_ne!(a.fingerprint(), c.fingerprint(), "ladder digest insensitive to seed");
}

/// Crash-recovery pin 1: the recovery machinery is invisible on
/// kill-free runs. Changing `store.recovery_lease_ms` or
/// `faas.checkpoint_ttl_s` (ladder off) must not move a single bit of a
/// default run — the reclamation sweep only acts on deaths, recovery
/// backoffs ride their own forked stream, and checkpoint staleness only
/// prices Restore-rung boots.
#[test]
fn recovery_config_invisible_without_kills() {
    let base = run_lambdafs_open(1234);
    assert_eq!(base.orphaned_ops, 0, "no kills, no orphans");
    assert_eq!(base.locks_reclaimed, 0, "no kills, no stranded locks");
    assert_eq!(base.audit_violations, 0, "healthy run audits clean");

    let run_tweaked = |lease_ms: f64, ttl_s: f64| -> RunMetrics {
        let (mut cfg, ns, sampler) = fixture(1234);
        cfg.store.recovery_lease_ms = lease_ms;
        cfg.faas.checkpoint_ttl_s = ttl_s;
        let spec = OpenLoopSpec {
            schedule: ThroughputSchedule::constant(8, 800.0),
            mix: OpMix::spotify(),
            n_clients: 64,
            n_vms: 2,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        let mut rng = Rng::new(cfg.seed ^ 0xd0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    let m = run_tweaked(500.0, 0.5);
    assert_eq!(base.fingerprint(), m.fingerprint(), "recovery knobs perturbed a clean run");
    assert_eq!(base.outcome_fingerprint(), m.outcome_fingerprint(), "ledger diverged");
}

/// Crash-recovery pin 2: a kill-storm replay of the dir-reorg workload —
/// the regime where instances die mid-op every second — is deterministic
/// in the seed (plan in the trace header, chaos stream realigned),
/// orphans real work, conserves the intent ledger, and audits clean.
#[test]
fn kill_storm_dir_reorg_replay_deterministic_and_conserving() {
    fn run(seed: u64) -> RunMetrics {
        let mut cfg = SystemConfig::default();
        cfg.seed = seed;
        cfg.lambda_fs.n_deployments = 8;
        let params = NamespaceParams { n_dirs: 256, files_per_dir: 16, ..Default::default() };
        let mut ns_rng = Rng::new(seed);
        let ns = generate(&params, &mut ns_rng);
        let spec = DirReorgSpec::at_scale(0.005); // 20 s, ~250 file ops/s, 4 reorgs/s
        let meta = TraceMeta::new("dir-reorg-storm", seed, &params, 48, 2);
        let mut trace_rng = Rng::new(seed ^ 0xd1e);
        let mut trace = synth::dir_reorg(&spec, &ns, meta, &mut trace_rng);
        let end = spec.duration_s as u32;
        trace.chaos = ChaosPlan {
            n_vms: 2,
            kills: (1..end)
                .flat_map(|s| (0..4).map(move |d| KillEvent { second: s, deployment: d }))
                .collect(),
            acks: vec![AckChaos { from_s: 0, to_s: end, drop_prob: 0.35, delay_ms: 250.0 }],
            ..ChaosPlan::none()
        };
        // The plan rides the binary format with the ops.
        let decoded = Trace::decode(&trace.encode()).expect("decode dir-reorg trace");
        assert_eq!(trace, decoded);
        replay_into(LambdaFs::new(cfg, ns, 48, 2), &decoded, &mut Rng::new(seed ^ 0x5eed))
    }

    let a = run(606);
    let b = run(606);
    assert_eq!(a.fingerprint(), b.fingerprint(), "kill-storm replays diverged");
    assert_eq!(a.outcome_fingerprint(), b.outcome_fingerprint(), "storm ledgers diverged");
    // The storm bites and the recovery protocol answers: orphans appear,
    // every one is replayed or aborted, stranded locks come back, and
    // the auditor never sees a lost acked write or stale read.
    assert!(a.orphaned_ops > 0, "per-second kills orphan in-flight ops");
    assert!(a.recovered_ops > 0, "durable intents replay with late acks");
    assert!(a.locks_reclaimed > 0, "stranded locks are reclaimed");
    assert_eq!(a.orphaned_ops, a.recovered_ops + a.aborted_ops, "intent conservation");
    assert_eq!(a.audit_violations, 0, "recovery never corrupts visible state");
    assert_eq!(a.cold_starts + a.warm_ops, a.completed_ops, "conservation under storm");
    let c = run(909);
    assert_ne!(a.fingerprint(), c.fingerprint(), "storm digest insensitive to seed");
}

/// Crash-recovery pin 3 (checkpoint aging): a ladder-on kill run with a
/// tiny `checkpoint_ttl_s` — so any Restore-rung boot pays the staleness
/// delta — stays deterministic in the seed and conserves both ledgers.
#[test]
fn checkpoint_aging_run_twice_identical() {
    fn run(seed: u64) -> RunMetrics {
        let (mut cfg, ns, sampler) = fixture(seed);
        cfg.faas.tier_ladder = true;
        cfg.faas.checkpoint_ttl_s = 0.5;
        let spec = OpenLoopSpec {
            schedule: ThroughputSchedule::constant(8, 800.0),
            mix: OpMix::spotify(),
            n_clients: 64,
            n_vms: 2,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        for (i, s) in (1..8).step_by(2).enumerate() {
            sys.schedule_kill(s, (i as u32) % 8);
        }
        let mut rng = Rng::new(cfg.seed ^ 0xd0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    }
    let a = run(1234);
    let b = run(1234);
    assert_eq!(a.fingerprint(), b.fingerprint(), "aged-checkpoint runs diverged");
    assert_eq!(a.outcome_fingerprint(), b.outcome_fingerprint(), "aged ledgers diverged");
    assert_eq!(a.pool_hits + a.restores + a.ephemeral_boots, a.cold_starts, "tier conservation");
    assert_eq!(a.orphaned_ops, a.recovered_ops + a.aborted_ops, "intent conservation");
    assert_eq!(a.audit_violations, 0, "aging never corrupts visible state");
}

/// Tier-ladder pin 3: the predictive prewarming policy is RNG-free, so a
/// predictive run is deterministic in the seed and composes with
/// record→replay bit for bit (the policy re-derives the same per-second
/// arrival deltas on both sides).
#[test]
fn predictive_policy_record_replay_bit_identical() {
    let seed = 2027u64;
    let (mut cfg, ns, sampler) = fixture(seed);
    cfg.faas.tier_ladder = true;
    cfg.lambda_fs.scale_policy = lambda_fs::config::ScalePolicyMode::Predictive;
    let params = NamespaceParams { n_dirs: 384, files_per_dir: 24, ..Default::default() };
    let mut sched_rng = Rng::new(seed ^ 0x5c);
    let spec = OpenLoopSpec {
        schedule: ThroughputSchedule::pareto_bursty(6, 3, 600.0, 2.0, 7.0, &mut sched_rng),
        mix: OpMix::spotify(),
        n_clients: 64,
        n_vms: 2,
        namespace: params.clone(),
        zipf_s: 1.3,
    };
    let meta = TraceMeta::new("spotify-predictive", seed, &params, spec.n_clients, spec.n_vms);

    let mut rec =
        Recorder::new(LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms), meta);
    let mut rng = Rng::new(cfg.seed ^ 0xabcd);
    driver::run_open_loop(&mut rec, &spec, &ns, &sampler, &mut rng);
    let (sys, trace) = rec.into_parts();
    let m_rec = sys.into_metrics();
    assert_eq!(
        m_rec.pool_hits + m_rec.restores + m_rec.ephemeral_boots,
        m_rec.cold_starts,
        "tier conservation under predictive prewarming"
    );

    let decoded = Trace::decode(&trace.encode()).expect("decode predictive trace");
    let m_rep = replay_into(
        LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms),
        &decoded,
        &mut Rng::new(cfg.seed ^ 0xabcd),
    );
    assert_eq!(
        m_rec.fingerprint(),
        m_rep.fingerprint(),
        "predictive record→replay must reproduce the run bit for bit"
    );
    assert_eq!(m_rec.outcome_fingerprint(), m_rep.outcome_fingerprint());
    assert_eq!(m_rec.pool_hits, m_rep.pool_hits);
    assert_eq!(m_rec.restores, m_rep.restores);

    // Run-twice identity for the live (non-replay) predictive path.
    let rerun = |_: ()| -> RunMetrics {
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        let mut rng = Rng::new(cfg.seed ^ 0xd0);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        sys.into_metrics()
    };
    assert_eq!(rerun(()).outcome_fingerprint(), rerun(()).outcome_fingerprint());
}
