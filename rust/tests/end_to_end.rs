//! End-to-end integration: small paper-shaped workloads across all
//! systems, asserting the qualitative results the paper reports.

use lambda_fs::baselines::{CephFs, HopsFs, InfiniCacheMds};
use lambda_fs::config::{AutoScaleMode, SystemConfig};
use lambda_fs::namespace::generate::{generate, HotspotSampler, NamespaceParams};
use lambda_fs::namespace::{Namespace, OpKind};
use lambda_fs::systems::{driver, LambdaFs, MetadataService};
use lambda_fs::util::rng::Rng;
use lambda_fs::workload::{ClosedLoopSpec, OpMix, OpenLoopSpec, ThroughputSchedule};

fn fixtures() -> (SystemConfig, Namespace, HotspotSampler, Rng) {
    let mut cfg = SystemConfig::default();
    cfg.lambda_fs.n_deployments = 8;
    let mut rng = Rng::new(cfg.seed);
    let ns = generate(
        &NamespaceParams { n_dirs: 1024, files_per_dir: 32, ..Default::default() },
        &mut rng,
    );
    let sampler = HotspotSampler::new(&ns, 1.3, &mut rng);
    (cfg, ns, sampler, rng)
}

/// A scaled-down Spotify workload: constant base + one 5x burst.
fn mini_spotify(base: f64, secs: usize) -> OpenLoopSpec {
    OpenLoopSpec {
        schedule: ThroughputSchedule::constant(secs, base).with_burst(secs / 2, 5, base * 5.0),
        mix: OpMix::spotify(),
        n_clients: 128,
        n_vms: 4,
        namespace: NamespaceParams::default(),
        zipf_s: 1.3,
    }
}

#[test]
fn lambdafs_beats_hopsfs_on_reads_and_loses_on_writes() {
    let (cfg, ns, sampler, mut rng) = fixtures();
    let spec = mini_spotify(2_000.0, 30);

    let mut lfs = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    driver::run_open_loop(&mut lfs, &spec, &ns, &sampler, &mut rng);
    let m_lfs = lfs.into_metrics();

    let mut hops = HopsFs::new(cfg, ns.clone(), 512.0, false);
    driver::run_open_loop(&mut hops, &spec, &ns, &sampler, &mut rng);
    let m_hops = hops.into_metrics();

    // Paper §5.2.2: λFS reads ~10x faster (warm path); writes slower
    // because of the coherence protocol.
    let lfs_read_p50 = m_lfs.read_lat.p50();
    let hops_read_p50 = m_hops.read_lat.p50();
    assert!(
        lfs_read_p50 < hops_read_p50,
        "λFS read p50 {lfs_read_p50}µs < HopsFS {hops_read_p50}µs"
    );
    assert!(
        m_lfs.avg_write_latency_ms() > m_hops.avg_write_latency_ms(),
        "coherence makes λFS writes slower: {} vs {}",
        m_lfs.avg_write_latency_ms(),
        m_hops.avg_write_latency_ms()
    );
    // Both complete the workload.
    assert_eq!(m_lfs.completed_ops, m_hops.completed_ops);
}

#[test]
fn lambdafs_cost_is_fraction_of_hopsfs() {
    let (cfg, ns, sampler, mut rng) = fixtures();
    let spec = mini_spotify(2_000.0, 30);

    let mut lfs = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    driver::run_open_loop(&mut lfs, &spec, &ns, &sampler, &mut rng);
    let m_lfs = lfs.into_metrics();

    let mut hops = HopsFs::new(cfg, ns.clone(), 512.0, false);
    driver::run_open_loop(&mut hops, &spec, &ns, &sampler, &mut rng);
    let m_hops = hops.into_metrics();

    // Paper Fig. 9: 85.99% cheaper (7.14x). Assert a strong direction.
    assert!(
        m_lfs.total_cost() < m_hops.total_cost() * 0.5,
        "λFS ${} vs HopsFS ${}",
        m_lfs.total_cost(),
        m_hops.total_cost()
    );
    // Simplified pricing costs more than pay-per-use (Fig. 9).
    assert!(m_lfs.total_cost_simplified() > m_lfs.total_cost());
}

#[test]
fn autoscaling_ablation_ordering() {
    // Fig. 14: enabled > limited > disabled for read throughput.
    let (cfg, ns, sampler, mut rng) = fixtures();
    let mut run = |mode: AutoScaleMode, rng: &mut Rng| {
        let mut c = cfg.clone();
        c.lambda_fs.autoscale = mode;
        let spec = ClosedLoopSpec {
            kind: OpKind::Read,
            n_clients: 768, // enough demand to saturate the disabled fleet
            n_vms: 4,
            ops_per_client: 200,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut sys = LambdaFs::new(c, ns.clone(), spec.n_clients, spec.n_vms);
        sys.prewarm(1); // λFS is a running service when the bench starts
        driver::run_closed_loop(&mut sys, &spec, &ns, &sampler, rng);
        sys.into_metrics().sustained_throughput()
    };
    let enabled = run(AutoScaleMode::Enabled, &mut rng);
    let limited = run(AutoScaleMode::Limited(2), &mut rng);
    let disabled = run(AutoScaleMode::Disabled, &mut rng);
    // enabled ≈ limited at this modest load (both absorb it); disabled
    // (one instance per deployment) clearly trails.
    assert!(
        enabled > limited * 0.85 && limited > disabled,
        "fig14 ordering: {enabled} ~ {limited} > {disabled}"
    );
    // (The paper's 2.85x+ gap needs the full 1,024-client/512-vCPU scale;
    // this integration check asserts a clear, stable margin.)
    assert!(enabled > disabled * 1.15, "auto-scaling matters: {enabled} vs {disabled}");
}

#[test]
fn infinicache_fails_where_lambdafs_succeeds() {
    let (cfg, ns, sampler, mut rng) = fixtures();
    let spec = mini_spotify(4_000.0, 20);

    let mut lfs = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
    driver::run_open_loop(&mut lfs, &spec, &ns, &sampler, &mut rng);
    let m_lfs = lfs.into_metrics();

    let mut inf = InfiniCacheMds::new(cfg, ns.clone(), 8);
    driver::run_open_loop(&mut inf, &spec, &ns, &sampler, &mut rng);
    let m_inf = inf.into_metrics();

    // λFS finishes roughly on schedule; InfiniCache's run sprawls far
    // past the schedule (it cannot sustain the load).
    assert!(m_lfs.seconds.len() < m_inf.seconds.len());
    assert!(
        m_inf.avg_latency_ms() > m_lfs.avg_latency_ms() * 3.0,
        "InfiniCache {}ms vs λFS {}ms",
        m_inf.avg_latency_ms(),
        m_lfs.avg_latency_ms()
    );
}

#[test]
fn cephfs_wins_small_scale_lambdafs_wins_large() {
    let (cfg, ns, sampler, mut rng) = fixtures();
    let run_pair = |n_clients: u32, rng: &mut Rng| {
        let spec = ClosedLoopSpec {
            kind: OpKind::Read,
            n_clients,
            n_vms: 4,
            ops_per_client: 300,
            namespace: NamespaceParams::default(),
            zipf_s: 1.3,
        };
        let mut l = LambdaFs::new(cfg.clone(), ns.clone(), n_clients, 4);
        driver::run_closed_loop(&mut l, &spec, &ns, &sampler, rng);
        let lt = l.into_metrics().peak_throughput();
        let mut c = CephFs::new(cfg.clone(), ns.clone(), 512.0);
        driver::run_closed_loop(&mut c, &spec, &ns, &sampler, rng);
        let ct = c.into_metrics().peak_throughput();
        (lt, ct)
    };
    // Large scale: λFS overtakes (paper Fig. 11: CephFS "fails to scale
    // well beyond" the first sizes).
    let (l_big, c_big) = run_pair(1024, &mut rng);
    assert!(l_big > c_big, "λFS at scale: {l_big} vs CephFS {c_big}");
}

#[test]
fn deterministic_across_identical_runs() {
    let (cfg, ns, sampler, _) = fixtures();
    let spec = mini_spotify(1_000.0, 10);
    let run = || {
        let mut rng = Rng::new(777);
        let mut sys = LambdaFs::new(cfg.clone(), ns.clone(), spec.n_clients, spec.n_vms);
        driver::run_open_loop(&mut sys, &spec, &ns, &sampler, &mut rng);
        let m = sys.into_metrics();
        (
            m.completed_ops,
            m.peak_throughput() as u64,
            (m.avg_latency_ms() * 1e6) as u64,
            (m.total_cost() * 1e9) as u64,
        )
    };
    assert_eq!(run(), run(), "same seed, same metrics, bit for bit");
}
