//! Integration: the compiled PJRT artifacts must be bit-identical to the
//! pure-Rust fallbacks (the L1/L2 ↔ L3 contract).
//!
//! Requires `make artifacts` (skipped with a notice if absent).

use lambda_fs::client::Router;
use lambda_fs::namespace::generate::{generate, NamespaceParams};
use lambda_fs::runtime::{artifacts_dir, ArtifactSet};
use lambda_fs::scaling::window::LatencyWindow;
use lambda_fs::util::dist::Pareto;
use lambda_fs::util::fnv;
use lambda_fs::util::rng::Rng;

fn artifacts() -> Option<ArtifactSet> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("SKIP: built without --features pjrt — PJRT runtime is stubbed");
        return None;
    }
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts/ not found — run `make artifacts`");
        return None;
    }
    Some(ArtifactSet::load_default().expect("artifacts load"))
}

#[test]
fn route_kernel_matches_rust_fnv() {
    let Some(set) = artifacts() else { return };
    let paths = vec![
        "/",
        "/dir",
        "/dir/note.pdf",
        "/nts",
        "/bks",
        "/a/very/deep/nested/directory/tree",
        "",
        "/spotify/user/12345/playlists",
    ];
    for n_dep in [1u32, 5, 16, 97] {
        let routed = set.route.route_batch(&paths, n_dep).unwrap();
        for (p, (dep, hash)) in paths.iter().zip(&routed) {
            assert_eq!(*hash, fnv::fnv1a32(p.as_bytes()), "hash mismatch for {p:?}");
            assert_eq!(*dep, fnv::route(p, n_dep), "dep mismatch for {p:?}");
        }
    }
}

#[test]
fn route_kernel_matches_on_generated_namespace() {
    let Some(set) = artifacts() else { return };
    let mut rng = Rng::new(42);
    let ns = generate(&NamespaceParams { n_dirs: 700, ..Default::default() }, &mut rng);
    let kernel_router = set.route.route_namespace(&ns, 16).unwrap();
    let rust_router = Router::build(&ns, 16);
    for d in &ns.dirs {
        let file = lambda_fs::namespace::InodeRef::file(d.id, 0);
        assert_eq!(
            kernel_router.route(&ns, file),
            rust_router.route(&ns, file),
            "router tables diverge at {}",
            d.path
        );
    }
}

#[test]
fn route_kernel_handles_long_and_unicode_paths() {
    let Some(set) = artifacts() else { return };
    let long = "/x".repeat(300); // > PATH_WIDTH bytes
    let uni = "/データ/ファイル";
    let paths = vec![long.as_str(), uni];
    let routed = set.route.route_batch(&paths, 16).unwrap();
    for (p, (dep, hash)) in paths.iter().zip(&routed) {
        let take = p.as_bytes().len().min(fnv::PATH_WIDTH);
        assert_eq!(*hash, fnv::fnv1a32(&p.as_bytes()[..take]));
        assert_eq!(*dep, fnv::route(p, 16));
    }
}

#[test]
fn latency_kernel_matches_rust_window() {
    let Some(set) = artifacts() else { return };
    let mut rng = Rng::new(7);
    let mut windows = Vec::new();
    let mut expect = Vec::new();
    for _ in 0..300 {
        let n = 1 + rng.below(64) as usize;
        let mut w = LatencyWindow::new(64);
        let mut flags = Default::default();
        for _ in 0..n {
            let lat = rng.range_f64(0.5, 20.0);
            flags = w.record(lat, 10.0, 2.5);
        }
        let (layout, count) = w.kernel_layout(64);
        windows.push((layout, count));
        expect.push((w.mean(), flags));
    }
    let verdicts = set.latency.evaluate(&windows, 10.0, 2.5).unwrap();
    assert_eq!(verdicts.len(), 300);
    for (i, v) in verdicts.iter().enumerate() {
        let (mean, flags) = &expect[i];
        let rel = (v.mean_ms as f64 - mean).abs() / mean.max(1e-9);
        assert!(rel < 1e-4, "window {i}: mean {} vs {}", v.mean_ms, mean);
        assert_eq!(v.straggler, flags.straggler, "window {i} straggler");
        assert_eq!(v.thrash, flags.thrash, "window {i} thrash");
    }
}

#[test]
fn pareto_kernel_matches_rust_sampler() {
    let Some(set) = artifacts() else { return };
    let mut rng = Rng::new(3);
    let uniforms: Vec<f32> = (0..256).map(|_| rng.f64() as f32).collect();
    let out = set.pareto.schedule(&uniforms, 25_000.0, 2.0).unwrap();
    assert_eq!(out.len(), uniforms.len());
    let p = Pareto::new(25_000.0, 2.0);
    let _ = p; // formula checked directly below
    for (u, d) in uniforms.iter().zip(&out) {
        let expect = 25_000.0f64 * (1.0 - (*u as f64).min(1.0 - 1e-7)).powf(-0.5);
        let rel = (*d as f64 - expect).abs() / expect;
        assert!(rel < 1e-3, "u={u}: {d} vs {expect}");
        assert!(*d >= 25_000.0 * 0.999, "support starts at x_m");
    }
}

#[test]
fn lambdafs_accepts_kernel_built_router() {
    let Some(set) = artifacts() else { return };
    let cfg = lambda_fs::config::SystemConfig::default();
    let mut rng = Rng::new(cfg.seed);
    let ns = generate(&NamespaceParams { n_dirs: 256, ..Default::default() }, &mut rng);
    let router = set.route.route_namespace(&ns, cfg.lambda_fs.n_deployments).unwrap();
    let sys = lambda_fs::systems::LambdaFs::new(cfg, ns, 16, 2).with_router(router);
    drop(sys); // construction validates deployment count
}
