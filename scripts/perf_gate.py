#!/usr/bin/env python3
"""Perf regression gate: validate BENCH_perf.json and compare it against
the committed BENCH_baseline.json.

Usage:
    perf_gate.py BENCH_perf.json BENCH_baseline.json [--write-baseline OUT]

Behaviour:
  * Always validates the BENCH_perf.json schema (all required hot spots
    present with positive baseline/current/speedup numbers).
  * Emits a markdown delta table (to stdout, and appended to
    $GITHUB_STEP_SUMMARY when set).
  * When the committed baseline is calibrated, ops/s regressions beyond
    the tolerance FAIL the gate for the spots listed in "gated"
    (e2e_submit, e2e_submit_batch) and WARN for every other spot.
  * When the committed baseline has "calibrated": false (bootstrap, or
    after a runner change), the gate runs in report-only mode and prints
    the calibrated baseline JSON to commit.
  * --write-baseline OUT writes that calibrated baseline to a file.

Exit codes: 0 ok / report-only, 1 schema violation or gated regression.
"""

import json
import os
import sys

REQUIRED_SPOTS = {
    "e2e_submit",
    "e2e_submit_batch",
    "e2e_sharded",
    "event_queue",
    "cache",
    "router",
    "store",
    "platform",
    "sampler",
    "hist",
}


def fail(msg):
    print(f"perf_gate: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def validate_schema(bench):
    if bench.get("schema") != "lambdafs-perf-v1":
        fail(f"unexpected BENCH_perf.json schema: {bench.get('schema')}")
    if bench.get("unit") != "ops_per_wall_second":
        fail(f"unexpected unit: {bench.get('unit')}")
    spots = bench.get("hot_spots", {})
    missing = REQUIRED_SPOTS - set(spots)
    if missing:
        fail(f"missing hot spots: {sorted(missing)}")
    for name, s in spots.items():
        for k in ("baseline", "current", "speedup"):
            v = s.get(k)
            if not isinstance(v, (int, float)) or v <= 0:
                fail(f"hot spot {name}: field {k} invalid: {v!r}")
    return spots


def main():
    argv = sys.argv[1:]
    write_baseline = None
    if "--write-baseline" in argv:
        i = argv.index("--write-baseline")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            fail("--write-baseline requires an output path")
        write_baseline = argv[i + 1]
        del argv[i : i + 2]
    args = argv
    if len(args) != 2 or any(a.startswith("--") for a in args):
        fail("usage: perf_gate.py BENCH_perf.json BENCH_baseline.json [--write-baseline OUT]")
    with open(args[0]) as f:
        bench = json.load(f)
    with open(args[1]) as f:
        base = json.load(f)

    spots = validate_schema(bench)
    if base.get("schema") != "lambdafs-perf-baseline-v1":
        fail(f"unexpected baseline schema: {base.get('schema')}")
    calibrated = bool(base.get("calibrated", False))
    tolerance = float(base.get("tolerance", 0.15))
    gated = set(base.get("gated", []))
    base_spots = base.get("hot_spots", {})

    rows = []
    failures = []
    warnings = []
    uncalibrated_spots = []
    order = sorted(spots, key=lambda k: (k not in gated, k))
    for name in order:
        cur = spots[name]["current"]
        committed = (base_spots.get(name) or {}).get("ops_per_wall_second")
        gate = "gate" if name in gated else "warn"
        if not calibrated or committed is None:
            if calibrated and name in gated:
                # A baseline claiming calibration must carry a floor for
                # every gated spot — otherwise the tentpole regressions
                # it exists to catch could never fail CI.
                fail(f"baseline is calibrated but gated spot {name} has no committed floor")
            uncalibrated_spots.append(name)
            rows.append((name, "—", f"{cur:,.0f}", "—", f"({gate}, uncalibrated)"))
            continue
        delta = (cur - committed) / committed
        status = "ok"
        if delta < -tolerance:
            status = "REGRESSION" if name in gated else "warn"
            line = (
                f"{name}: current {cur:,.0f} ops/s is {-delta * 100:.1f}% below "
                f"committed baseline {committed:,.0f} ops/s (tolerance {tolerance * 100:.0f}%)"
            )
            (failures if name in gated else warnings).append(line)
        rows.append((name, f"{committed:,.0f}", f"{cur:,.0f}", f"{delta * 100:+.1f}%", status))

    md = ["## Perf regression gate", ""]
    if calibrated:
        md.append(
            f"Committed baseline vs this run (ops/wall-second); gated spots "
            f"({', '.join(sorted(gated))}) fail CI beyond {tolerance * 100:.0f}%."
        )
    else:
        md.append(
            "**Baseline is uncalibrated** — report-only. Commit the calibrated "
            "baseline below (from a CI runner) to arm the gate."
        )
    if uncalibrated_spots:
        # Loud counter: every run without committed floors shouts how much
        # of the suite is unenforced, so an uncalibrated gate cannot pass
        # silently for months.
        md.append(
            f"\n> ### ⚠️ UNCALIBRATED RUN — {len(uncalibrated_spots)}/{len(order)} hot "
            f"spots have no committed floor\n"
            f"> Unenforced: {', '.join(sorted(uncalibrated_spots))}. Regressions in "
            f"these spots CANNOT fail CI until a calibrated baseline is committed."
        )
    md += ["", "| hot spot | committed | current | delta | status |", "|---|---|---|---|---|"]
    for r in rows:
        md.append("| " + " | ".join(r) + " |")
    for w in warnings:
        md.append(f"\n> ⚠️ {w}")
    for f_ in failures:
        md.append(f"\n> ❌ {f_}")

    calibrated_out = {
        "schema": "lambdafs-perf-baseline-v1",
        "calibrated": True,
        "tolerance": tolerance,
        "gated": sorted(gated) if gated else ["e2e_submit", "e2e_submit_batch"],
        "note": (
            "ops/wall-second floors for the perf regression gate; recalibrate "
            "(scripts/perf_gate.py --write-baseline) when the CI runner class changes"
        ),
        "hot_spots": {
            name: {"ops_per_wall_second": round(spots[name]["current"])} for name in sorted(spots)
        },
    }
    if not calibrated:
        md += [
            "",
            "```json",
            json.dumps(calibrated_out, indent=2),
            "```",
        ]
    if write_baseline:
        with open(write_baseline, "w") as f:
            json.dump(calibrated_out, f, indent=2)
            f.write("\n")
        print(f"perf_gate: wrote calibrated baseline to {write_baseline}")

    text = "\n".join(md) + "\n"
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text)

    e2e = spots["e2e_submit"]
    print(
        f"e2e submit: {e2e['baseline']:.0f} -> {e2e['current']:.0f} ops/s "
        f"({e2e['speedup']:.2f}x)"
    )
    plat = spots["platform"]
    print(
        f"platform churn: {plat['baseline']:.0f} -> {plat['current']:.0f} ops/s "
        f"({plat['speedup']:.2f}x, arena vs reference)"
    )
    if failures:
        for f_ in failures:
            print(f"perf_gate: FAIL: {f_}", file=sys.stderr)
        sys.exit(1)
    if uncalibrated_spots:
        print(
            f"perf_gate: WARNING: UNCALIBRATED RUN — {len(uncalibrated_spots)}/{len(order)} "
            f"hot spots unenforced ({', '.join(sorted(uncalibrated_spots))})",
            file=sys.stderr,
        )
    print("perf_gate: OK" + ("" if calibrated else " (report-only: baseline uncalibrated)"))


if __name__ == "__main__":
    main()
