#!/usr/bin/env python3
"""Validate a `lambdafs observe` Chrome trace-event JSON artifact.

Checks the three contracts the exporter promises:

1. **Viewer shape** — a `traceEvents` array in the Trace Event JSON
   Object Format: metadata/counter/instant phases only, counter args all
   numeric (Perfetto renders them as counter tracks), instant events
   global-scoped, and `ts` non-decreasing in rendered order.
2. **Track coverage** — every gauge of the per-second timeline sampler
   appears as a counter track, and the fault schedule that ran shows up
   as instant events (`kill`, `blackout start/end`) matching the counts
   in the summary section. Every kill is followed by exactly one
   `recovery sweep` instant, one recovery lease after the kill boundary
   — the moment the reclamation protocol replays-or-aborts the dead
   instance's open intents and releases its stranded locks.
3. **Conservation** — the `lambdafs` summary section's per-phase latency
   totals sum exactly to the end-to-end latency total (the span layer
   attributed every microsecond of every completed op to exactly one
   phase), the crash-recovery ledger conserves
   (`orphaned_ops == recovered_ops + aborted_ops`), and the always-on
   consistency auditor reports zero violations — on any artifact, chaos
   or not.

Usage: validate_trace_events.py <trace.json> [--expect-orphans]
`--expect-orphans` additionally requires orphaned_ops > 0 and
recovered_ops > 0 (for kill-storm artifacts, where the recovery
machinery must visibly fire). Exits non-zero with a message on the
first violated contract.
"""

import json
import sys

SCHEMA = "lambdafs-trace-events-v2"
SEC_US = 1_000_000
PHASES = ["queue", "cold", "net", "exec", "coherence", "store", "retry"]
COUNTER_TRACKS = [
    "live instances",
    "warm instances",
    "warm pool (instances)",
    "throughput (ops/s)",
    "backlog (ops)",
    "cache hit ratio (%)",
    "cost rate ($/s)",
    "faults (cumulative)",
    "recovered ops (cumulative)",
]


def fail(msg):
    print(f"validate_trace_events: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check(cond, msg):
    if not cond:
        fail(msg)


def main(path, expect_orphans=False):
    with open(path) as f:
        doc = json.load(f)

    check(isinstance(doc.get("traceEvents"), list), "traceEvents array missing")
    events = doc["traceEvents"]
    check(len(events) > 0, "empty traceEvents")

    last_ts = 0
    counter_names = set()
    instant_counts = {}
    instant_ts = {}
    for i, ev in enumerate(events):
        check(isinstance(ev.get("name"), str) and ev["name"], f"event {i}: no name")
        ph = ev.get("ph")
        check(ph in ("M", "C", "i"), f"event {i}: unexpected ph {ph!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        check(isinstance(ts, int) and ts >= 0, f"event {i}: bad ts {ts!r}")
        check(ts >= last_ts, f"event {i}: ts regressed {ts} < {last_ts}")
        last_ts = ts
        args = ev.get("args")
        check(isinstance(args, dict) and args, f"event {i}: no args")
        if ph == "C":
            counter_names.add(ev["name"])
            for k, v in args.items():
                check(
                    isinstance(v, (int, float)) and not isinstance(v, bool),
                    f"counter {ev['name']!r} arg {k!r} not numeric: {v!r}",
                )
        else:  # instant
            check(ev.get("s") == "g", f"instant {ev['name']!r}: scope {ev.get('s')!r}")
            instant_counts[ev["name"]] = instant_counts.get(ev["name"], 0) + 1
            instant_ts.setdefault(ev["name"], []).append(ts)

    for track in COUNTER_TRACKS:
        check(track in counter_names, f"counter track {track!r} missing")

    summary = doc.get("lambdafs")
    check(isinstance(summary, dict), "lambdafs summary section missing")
    check(summary.get("schema") == SCHEMA, f"schema {summary.get('schema')!r} != {SCHEMA!r}")
    check(summary.get("completed_ops", 0) > 0, "no completed ops")
    check(summary.get("seconds", 0) > 0, "no sampled seconds")

    totals = summary.get("phase_totals_us")
    check(isinstance(totals, dict), "phase_totals_us missing")
    check(sorted(totals) == sorted(PHASES), f"phase keys {sorted(totals)}")
    for name, quantiles in (("phase_p50_us", summary.get("phase_p50_us")),
                            ("phase_p99_us", summary.get("phase_p99_us"))):
        check(isinstance(quantiles, dict) and sorted(quantiles) == sorted(PHASES),
              f"{name} malformed")
    for p in PHASES:
        check(summary["phase_p50_us"][p] <= summary["phase_p99_us"][p] + 1e-9,
              f"phase {p}: p50 > p99")

    phase_sum = sum(totals.values())
    e2e = summary.get("e2e_total_us")
    check(isinstance(e2e, int), "e2e_total_us missing")
    check(
        phase_sum == e2e,
        f"conservation violated: sum(phase_totals_us)={phase_sum} != e2e_total_us={e2e}",
    )
    dom = summary.get("dominant_phase")
    check(dom in PHASES or (dom == "-" and phase_sum == 0), f"dominant_phase {dom!r}")
    if phase_sum > 0:
        check(totals[dom] == max(totals.values()), "dominant_phase is not the max phase")

    # Crash-recovery ledger: the intent log never loses an orphan (every
    # one is replayed or aborted), the auditor is clean, and every kill
    # has exactly one recovery-sweep instant one lease past its boundary.
    for k in ("orphaned_ops", "recovered_ops", "aborted_ops",
              "locks_reclaimed", "audit_violations", "recovery_lease_us"):
        check(isinstance(summary.get(k), int) and summary[k] >= 0, f"{k} missing/bad")
    check(
        summary["orphaned_ops"] == summary["recovered_ops"] + summary["aborted_ops"],
        f"orphan conservation violated: {summary['orphaned_ops']} != "
        f"{summary['recovered_ops']} + {summary['aborted_ops']}",
    )
    check(
        summary["audit_violations"] == 0,
        f"consistency auditor reported {summary['audit_violations']} violations",
    )
    if expect_orphans:
        check(summary["orphaned_ops"] > 0, "--expect-orphans: no ops were orphaned")
        check(summary["recovered_ops"] > 0, "--expect-orphans: no ops were recovered")

    kills = summary.get("kills", 0)
    if kills > 0:
        check(
            instant_counts.get("kill", 0) == kills,
            f"{kills} kills in summary, {instant_counts.get('kill', 0)} kill instants",
        )
        lease = summary["recovery_lease_us"]
        expected_sweeps = sorted(t + SEC_US + lease for t in instant_ts.get("kill", []))
        check(
            sorted(instant_ts.get("recovery sweep", [])) == expected_sweeps,
            "recovery sweeps do not match kill boundaries + lease",
        )
    blackouts = summary.get("blackouts", 0)
    if blackouts > 0:
        check(
            instant_counts.get("blackout start", 0) == blackouts,
            f"{blackouts} blackouts, {instant_counts.get('blackout start', 0)} start instants",
        )

    n_events = len(events)
    print(
        f"validate_trace_events: OK — {n_events} events, {len(counter_names)} counter "
        f"tracks, {summary['seconds']} s sampled, phase sum {phase_sum} us == e2e "
        f"({dom} dominant), {summary['orphaned_ops']} orphaned = "
        f"{summary['recovered_ops']} recovered + {summary['aborted_ops']} aborted"
    )


if __name__ == "__main__":
    argv = [a for a in sys.argv[1:] if a != "--expect-orphans"]
    if len(argv) != 1:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    main(argv[0], expect_orphans="--expect-orphans" in sys.argv[1:])
